//! **Maglev hashing** (Eisenbud et al., NSDI 2016): Google's load-balancer
//! table.  Each bucket fills a fixed-size prime lookup table via a
//! per-bucket permutation (offset/skip); lookups are a single table index
//! — O(1) with O(table) memory and O(n·table) rebuild on change.
//! Near-perfect balance, but only *approximate* minimal disruption (a
//! rebuild may move a small fraction of unrelated keys) — the documented
//! trade-off this table-based family makes vs. the stateless family.

use crate::hashing::hash2;

use super::ConsistentHasher;

/// Default table size (prime, ~100× max buckets as the paper recommends).
pub const DEFAULT_TABLE: u32 = 65537;

/// Maglev lookup table.
#[derive(Debug, Clone)]
pub struct Maglev {
    table: Vec<u32>,
    n: u32,
    table_size: u32,
}

/// Smallest prime `>= x` (trial division; construction-time only).
fn next_prime(mut x: u32) -> u32 {
    if x <= 2 {
        return 2;
    }
    if x % 2 == 0 {
        x += 1;
    }
    loop {
        let mut is_prime = true;
        let mut d = 3u32;
        while (d as u64) * (d as u64) <= x as u64 {
            if x % d == 0 {
                is_prime = false;
                break;
            }
            d += 2;
        }
        if is_prime {
            return x;
        }
        x += 2;
    }
}

impl Maglev {
    /// Create with `n` buckets over the default prime table (auto-grown to
    /// a prime `>= 8n` when `n` is large, per the paper's ~100× guidance
    /// scaled to memory budget).
    pub fn new(n: u32) -> Self {
        let table = if DEFAULT_TABLE >= n.saturating_mul(8) {
            DEFAULT_TABLE
        } else {
            next_prime(n.saturating_mul(8) | 1)
        };
        Self::with_table_size(n, table)
    }

    /// Create with an explicit (prime) table size.
    pub fn with_table_size(n: u32, table_size: u32) -> Self {
        assert!(n >= 1 && table_size >= n);
        let mut this = Self { table: Vec::new(), n, table_size };
        this.rebuild();
        this
    }

    /// Populate the table with the published permutation-fill algorithm.
    fn rebuild(&mut self) {
        let m = self.table_size as u64;
        let n = self.n as usize;
        let mut offset = vec![0u64; n];
        let mut skip = vec![0u64; n];
        for b in 0..n {
            offset[b] = hash2(b as u64, 0x0FF_5E7) % m;
            skip[b] = hash2(b as u64, 0x5C1B) % (m - 1) + 1;
        }
        let mut next = vec![0u64; n];
        let mut table = vec![u32::MAX; m as usize];
        let mut filled = 0u64;
        'outer: loop {
            for b in 0..n {
                // Walk b's permutation to its next unclaimed slot.
                loop {
                    let c = ((offset[b] + next[b] * skip[b]) % m) as usize;
                    next[b] += 1;
                    if table[c] == u32::MAX {
                        table[c] = b as u32;
                        filled += 1;
                        if filled == m {
                            break 'outer;
                        }
                        break;
                    }
                }
            }
        }
        self.table = table;
    }
}

impl ConsistentHasher for Maglev {
    fn name(&self) -> &'static str {
        "maglev"
    }

    fn len(&self) -> u32 {
        self.n
    }

    #[inline]
    fn bucket(&self, digest: u64) -> u32 {
        self.table[(digest % self.table_size as u64) as usize]
    }

    fn add_bucket(&mut self) -> u32 {
        self.n += 1;
        self.rebuild();
        self.n - 1
    }

    fn remove_bucket(&mut self) -> u32 {
        assert!(self.n > 1);
        self.n -= 1;
        self.rebuild();
        self.n
    }

    fn fork(&self) -> Box<dyn ConsistentHasher> {
        Box::new(self.clone())
    }

    // A table rebuild may move a small fraction of keys between surviving
    // buckets, so a scale-down must scan every shard, not just the
    // retiring one.
    fn minimal_disruption(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::SplitMix64Rng;

    #[test]
    fn table_fully_assigned() {
        let m = Maglev::with_table_size(7, 251);
        assert!(m.table.iter().all(|&b| b < 7));
    }

    #[test]
    fn near_perfect_balance() {
        let m = Maglev::with_table_size(10, 65537);
        let mut counts = vec![0u32; 10];
        for &b in &m.table {
            counts[b as usize] += 1;
        }
        let mean = m.table.len() as f64 / 10.0;
        for c in counts {
            assert!((c as f64 - mean).abs() < 0.02 * mean, "c={c} mean={mean}");
        }
    }

    #[test]
    fn mostly_minimal_disruption() {
        // Maglev guarantees only *approximate* disruption: adding a bucket
        // should move ~1/(n+1) of keys, with a small extra fraction.
        let mut m = Maglev::with_table_size(8, 65537);
        let mut rng = SplitMix64Rng::new(3);
        let digests: Vec<u64> = (0..20_000).map(|_| rng.next_u64()).collect();
        let before: Vec<u32> = digests.iter().map(|&d| m.bucket(d)).collect();
        m.add_bucket();
        let moved = digests
            .iter()
            .zip(&before)
            .filter(|&(&d, &b)| m.bucket(d) != b)
            .count() as f64
            / digests.len() as f64;
        assert!(moved < 0.25, "moved fraction {moved}");
        assert!(moved > 0.05, "suspiciously little movement {moved}");
    }
}
