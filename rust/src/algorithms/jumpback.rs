//! **JumpBackHash** (Ertl, 2024) — documented reconstruction.
//!
//! Published profile: expected-constant time, *integer arithmetic only*,
//! no modulo/division, minimal memory, a drop-in replacement for JumpHash.
//!
//! Reconstruction strategy (see the module docs in `algorithms`): the
//! four 2023/24 constant-time
//! algorithms share one provably-consistent core — map into the enclosing
//! power-of-two range, retry invalid candidates with fresh hashes, fall
//! back to a minor-range remap that is *identical* to the lookup at the
//! boundary size (the property that makes era changes seamless; see the
//! BinomialHash paper §5.3).  The original's exact per-era candidate
//! sampler was not recoverable, so this implementation keeps that core and
//! realizes JumpBackHash's distinguishing trait — cheap *chained integer*
//! draws (one add + one finalize per attempt, no modulo, no re-keying,
//! no floating point) — with its own rehash stream constants.  Relative
//! benchmark claims are preserved for the structural reason the paper
//! gives: its per-attempt cost is the same handful of integer ops as
//! BinomialHash, so the two are statistically tied (Fig. 5).

use crate::hashing::{next_pow2, splitmix64};

use super::binomial::relocate_within_level;
use super::ConsistentHasher;

/// Attempt budget before the minor-range fallback (residual key mass
/// `< 2^-16`, far below measurement noise).
pub const ATTEMPTS: u32 = 16;

/// Rehash stream increment (Weyl constant distinct from BinomialHash's
/// PHI64 stream so the two algorithms are not bit-identical).
const STREAM: u64 = 0xD1B5_4A32_D192_ED03;

#[inline(always)]
fn next_draw(h: u64) -> u64 {
    splitmix64(h.wrapping_add(STREAM))
}

/// JumpBackHash lookup: digest × n → bucket (free function, hot path).
#[inline]
pub fn jumpback(digest: u64, n: u32) -> u32 {
    if n <= 1 {
        return 0;
    }
    let e = next_pow2(n as u64);
    let m = e >> 1;
    let mut hi = digest;
    for _ in 0..ATTEMPTS {
        let b = hi & (e - 1);
        let c = relocate_within_level(b, hi);
        if c < m {
            // Jump *back* to the key's placement at the boundary size m —
            // a pure function of (digest, m), so era transitions are
            // seamless and the minor range stays uniformly filled.
            let d = digest & (m - 1);
            return relocate_within_level(d, digest) as u32;
        }
        if c < n as u64 {
            return c as u32;
        }
        hi = next_draw(hi);
    }
    let d = digest & (m - 1);
    relocate_within_level(d, digest) as u32
}

/// JumpBackHash wrapped in the [`ConsistentHasher`] interface.
#[derive(Debug, Clone, Copy)]
pub struct JumpBackHash {
    n: u32,
}

impl JumpBackHash {
    /// Create with `n` buckets.
    pub fn new(n: u32) -> Self {
        assert!(n >= 1);
        Self { n }
    }
}

impl ConsistentHasher for JumpBackHash {
    fn name(&self) -> &'static str {
        "jumpback"
    }

    fn len(&self) -> u32 {
        self.n
    }

    #[inline]
    fn bucket(&self, digest: u64) -> u32 {
        jumpback(digest, self.n)
    }

    fn add_bucket(&mut self) -> u32 {
        self.n += 1;
        self.n - 1
    }

    fn remove_bucket(&mut self) -> u32 {
        assert!(self.n > 1);
        self.n -= 1;
        self.n
    }

    fn fork(&self) -> Box<dyn ConsistentHasher> {
        Box::new(*self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::SplitMix64Rng;

    #[test]
    fn in_range() {
        let mut rng = SplitMix64Rng::new(31);
        for n in [1u32, 2, 3, 5, 9, 16, 17, 255, 256, 257, 100_000] {
            for _ in 0..500 {
                assert!(jumpback(rng.next_u64(), n) < n);
            }
        }
    }

    #[test]
    fn distinct_from_binomial() {
        // Same consistency skeleton, different hash streams: mappings must
        // not be identical (they are different algorithms in the bench).
        let mut rng = SplitMix64Rng::new(32);
        let n = 23;
        let diff = (0..1_000)
            .filter(|_| {
                let d = rng.next_u64();
                jumpback(d, n) != super::super::binomial::lookup(d, n, 6)
            })
            .count();
        assert!(diff > 100, "only {diff} differing keys");
    }

    #[test]
    fn monotone_single_step() {
        let mut rng = SplitMix64Rng::new(14);
        for _ in 0..5_000 {
            let h = rng.next_u64();
            let n = 1 + rng.next_below(300) as u32;
            let before = jumpback(h, n);
            let after = jumpback(h, n + 1);
            assert!(after == before || after == n, "h={h} n={n} {before}->{after}");
        }
    }

    #[test]
    fn minimal_disruption_single_step() {
        let mut rng = SplitMix64Rng::new(15);
        for _ in 0..5_000 {
            let h = rng.next_u64();
            let n = 2 + rng.next_below(300) as u32;
            let before = jumpback(h, n);
            let after = jumpback(h, n - 1);
            if before != n - 1 {
                assert_eq!(after, before, "h={h} n={n}");
            }
        }
    }

    #[test]
    fn balanced_rough() {
        for n in [11u32, 24, 48] {
            let k = 10_000 * n;
            let mut counts = vec![0u32; n as usize];
            let mut rng = SplitMix64Rng::new(2);
            for _ in 0..k {
                counts[jumpback(rng.next_u64(), n) as usize] += 1;
            }
            let mean = k as f64 / n as f64;
            for c in counts {
                assert!((c as f64 - mean).abs() < 0.06 * mean, "n={n} c={c} mean={mean}");
            }
        }
    }
}
