//! **PowerCH** (Leu, 2023: *Fast consistent hashing in constant time*) —
//! documented reconstruction.
//!
//! Published profile: constant-time lookup, minimal constant memory,
//! **floating-point arithmetic** in the resolution step — the trait the
//! BinomialHash paper singles out to explain why PowerCH and FlipHash
//! trail the integer-only algorithms in Fig. 5.
//!
//! Reconstruction strategy (see the module docs in `algorithms`): the
//! provably-consistent core
//! (enclosing power-of-two range, congruent masks, retry, boundary-size
//! fallback) is shared — it is the only part of these algorithms whose
//! structure the consistency proofs pin down, and the congruent bit-mask
//! chain cannot be replaced by float scaling without breaking the §5.3
//! era-boundary collapse.  PowerCH's floating-point character therefore
//! lives where the proof permits any pure uniform function:
//!
//! * the within-level relocation offset is computed as `⌊u · 2^d⌋` from a
//!   53-bit unit float (an FP multiply + floor per relocation), and
//! * candidate acceptance runs through f64 conversions and FP compares.
//!
//! That is 3-6 FP ops per lookup versus zero in BinomialHash/JumpBackHash
//! — reproducing the paper's measured ordering for its stated reason.

use crate::hashing::{hash2, next_pow2, splitmix64};

use super::ConsistentHasher;

/// Attempt cap before the boundary fallback.
pub const ATTEMPTS: u32 = 16;

/// Rehash stream increment (distinct from the other algorithms' streams).
const STREAM: u64 = 0xA24B_AED4_963E_E407;

const INV_2_53: f64 = 1.0 / 9007199254740992.0; // 2^-53

#[inline(always)]
fn next_draw(h: u64) -> u64 {
    splitmix64(h.wrapping_add(STREAM))
}

/// Float-flavoured within-level relocation: same level-preserving
/// contract as Alg. 2, offset computed in f64.
#[inline(always)]
fn relocate_float(b: u64, h: u64) -> u64 {
    if b < 2 {
        return b;
    }
    let d = 63 - b.leading_zeros();
    let f = (1u64 << d) - 1;
    let u = (hash2(h, f) >> 11) as f64 * INV_2_53; // unit float
    let i = (u * (1u64 << d) as f64) as u64; // FP multiply + floor
    (1u64 << d) + i.min(f)
}

/// PowerCH lookup: digest × n → bucket (free function, hot path).
#[inline]
pub fn powerch(digest: u64, n: u32, attempts: u32) -> u32 {
    if n <= 1 {
        return 0;
    }
    let e = next_pow2(n as u64);
    let m = e >> 1;
    let m_f = m as f64;
    let n_f = n as f64;
    let mut hi = digest;
    for _ in 0..attempts {
        let b = hi & (e - 1);
        let c = relocate_float(b, hi);
        let c_f = c as f64; // FP acceptance tests (values < 2^53: exact)
        if c_f < m_f {
            let d = digest & (m - 1);
            return relocate_float(d, digest) as u32;
        }
        if c_f < n_f {
            return c as u32;
        }
        hi = next_draw(hi);
    }
    let d = digest & (m - 1);
    relocate_float(d, digest) as u32
}

/// PowerCH wrapped in the [`ConsistentHasher`] interface.
#[derive(Debug, Clone, Copy)]
pub struct PowerCh {
    n: u32,
    attempts: u32,
}

impl PowerCh {
    /// Create with `n` buckets and the default attempt cap.
    pub fn new(n: u32) -> Self {
        assert!(n >= 1);
        Self { n, attempts: ATTEMPTS }
    }
}

impl ConsistentHasher for PowerCh {
    fn name(&self) -> &'static str {
        "powerch"
    }

    fn len(&self) -> u32 {
        self.n
    }

    #[inline]
    fn bucket(&self, digest: u64) -> u32 {
        powerch(digest, self.n, self.attempts)
    }

    fn add_bucket(&mut self) -> u32 {
        self.n += 1;
        self.n - 1
    }

    fn remove_bucket(&mut self) -> u32 {
        assert!(self.n > 1);
        self.n -= 1;
        self.n
    }

    fn fork(&self) -> Box<dyn ConsistentHasher> {
        Box::new(*self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::SplitMix64Rng;

    #[test]
    fn in_range() {
        let mut rng = SplitMix64Rng::new(44);
        for n in [1u32, 2, 3, 9, 16, 17, 1000, 65_537] {
            for _ in 0..500 {
                assert!(powerch(rng.next_u64(), n, ATTEMPTS) < n);
            }
        }
    }

    #[test]
    fn relocate_float_preserves_level() {
        let mut rng = SplitMix64Rng::new(45);
        for _ in 0..5_000 {
            let b = 2 + rng.next_below((1 << 30) - 2);
            let h = rng.next_u64();
            let c = relocate_float(b, h);
            assert_eq!(63 - c.leading_zeros(), 63 - b.leading_zeros(), "b={b} c={c}");
        }
    }

    #[test]
    fn monotone_single_step() {
        let mut rng = SplitMix64Rng::new(13);
        for _ in 0..5_000 {
            let h = rng.next_u64();
            let n = 1 + rng.next_below(300) as u32;
            let before = powerch(h, n, ATTEMPTS);
            let after = powerch(h, n + 1, ATTEMPTS);
            assert!(after == before || after == n, "h={h} n={n} {before}->{after}");
        }
    }

    #[test]
    fn minimal_disruption_single_step() {
        let mut rng = SplitMix64Rng::new(16);
        for _ in 0..5_000 {
            let h = rng.next_u64();
            let n = 2 + rng.next_below(300) as u32;
            let before = powerch(h, n, ATTEMPTS);
            let after = powerch(h, n - 1, ATTEMPTS);
            if before != n - 1 {
                assert_eq!(after, before, "h={h} n={n}");
            }
        }
    }

    #[test]
    fn balanced_rough() {
        for n in [11u32, 24] {
            let k = 10_000 * n;
            let mut counts = vec![0u32; n as usize];
            let mut rng = SplitMix64Rng::new(10);
            for _ in 0..k {
                counts[powerch(rng.next_u64(), n, ATTEMPTS) as usize] += 1;
            }
            let mean = k as f64 / n as f64;
            for c in counts {
                assert!((c as f64 - mean).abs() < 0.06 * mean, "n={n} c={c} mean={mean}");
            }
        }
    }

    #[test]
    fn lower_half_stable_under_growth() {
        // Keys whose enclosing-range candidate stays in the minor tree get
        // the same placement for every n in the era (9..=16).
        let mut rng = SplitMix64Rng::new(12);
        for _ in 0..2_000 {
            let h = rng.next_u64();
            let b9 = powerch(h, 9, ATTEMPTS);
            let mut prev = b9;
            for n in 10u32..=16 {
                let b = powerch(h, n, ATTEMPTS);
                assert!(b == prev || b == n - 1, "h={h} n={n} {prev}->{b}");
                prev = b;
            }
        }
    }
}
