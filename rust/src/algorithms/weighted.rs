//! `Weighted` — per-shard weights over any engine via virtual buckets.
//!
//! The classic answer to heterogeneous machines: run the wrapped engine
//! over `W = Σ weights` *virtual* buckets and map each virtual bucket to
//! the physical shard that owns it, so a shard with weight 2 owns twice
//! the virtual buckets — and twice the keyspace — of a weight-1 shard.
//! The adapter is itself a [`ConsistentHasher`], so everything layered on
//! placement (epoch snapshots, incremental migration, replication,
//! failover) composes unchanged, over all 13 engines.
//!
//! ## The LIFO bridge
//!
//! The wrapped engine only resizes at its LIFO tail, but weights must
//! change for *any* shard.  The bridge is the tail-reassignment trick in
//! [`Weighted::set_weight`]: to take a virtual bucket away from shard `s`
//! when the engine's tail virtual bucket `t` belongs to some other shard
//! `o`, remove `t` (legal: it is the tail) and hand one of `s`'s virtual
//! buckets to `o` — `o`'s count is unchanged, `s` is down one, and the
//! engine only ever saw a LIFO removal.  Keys move from at most two
//! virtual buckets per step, and the epoch-snapshot migration planner
//! picks the moves up exactly like a scale event — **weight changes are
//! incremental migrations for free**.
//!
//! ## Failover
//!
//! When the wrapped engine is [`FaultTolerant`], so is the adapter: a
//! physical failure removes every virtual bucket of the dead shard (in
//! recorded order), a restore brings them back in reverse, and ordering
//! constraints of the inner engine (anchor's reverse-removal rule)
//! surface through [`FaultTolerant::restore_blocked`] at shard
//! granularity.
//!
//! Uniform weight 1 is the identity layout (`owner[v] == v`), so a
//! `Weighted` wrapper at weight 1 everywhere is placement-identical to
//! the bare engine — pinned by `rust/tests/engine_fork.rs`.

use super::{by_name, ConsistentHasher, FaultTolerant};

/// Virtual-bucket weight adapter; see the module docs.
pub struct Weighted {
    /// Wrapped engine, running over virtual buckets.
    inner: Box<dyn ConsistentHasher>,
    /// Virtual bucket id → physical shard id.  Index space is the
    /// engine's full assignment range; entries for failed shards stay in
    /// place (their virtual buckets are removed from the engine, not
    /// from the map) so a restore can re-own them.
    owner: Vec<u32>,
    /// Physical shard id → its virtual-bucket count (the weight).
    weights: Vec<u32>,
    /// Weight assigned to shards joining via `add_bucket`.
    default_weight: u32,
    /// Failure log: `(shard, its virtual buckets in removal order)`,
    /// in failure order.  Restores replay each entry in reverse.
    failed: Vec<(u32, Vec<u32>)>,
}

impl Weighted {
    /// Wrap engine `engine` with one physical shard per entry of
    /// `weights`, each owning `weights[s]` virtual buckets.  New shards
    /// joining later via `add_bucket` get weight `default_weight`.
    ///
    /// Returns `None` for an unknown engine name; panics on an empty
    /// weight table or a zero weight (a weight-0 shard would own no
    /// keyspace — remove it instead).
    pub fn new(engine: &str, weights: &[u32], default_weight: u32) -> Option<Weighted> {
        assert!(!weights.is_empty(), "weighted: at least one shard required");
        assert!(weights.iter().all(|&w| w >= 1), "weighted: weights must be >= 1");
        assert!(default_weight >= 1, "weighted: default_weight must be >= 1");
        let total: u32 = weights.iter().sum();
        let inner = by_name(engine, total)?;
        let mut owner = Vec::with_capacity(total as usize);
        for (s, &w) in weights.iter().enumerate() {
            owner.extend(std::iter::repeat(s as u32).take(w as usize));
        }
        Some(Weighted { inner, owner, weights: weights.to_vec(), default_weight, failed: Vec::new() })
    }

    /// Uniform weight-1 wrapper over `n` shards — placement-identical to
    /// the bare engine.
    pub fn uniform(engine: &str, n: u32) -> Option<Weighted> {
        Self::new(engine, &vec![1; n as usize], 1)
    }

    /// The per-shard weight table (index = physical shard id).
    pub fn weights(&self) -> &[u32] {
        &self.weights
    }

    /// Total virtual buckets currently assigned.
    pub fn virtual_buckets(&self) -> u32 {
        self.owner.len() as u32
    }

    /// Change shard `shard`'s weight to `w` (≥ 1), growing or shrinking
    /// its virtual-bucket share at the wrapped engine's LIFO tail (see
    /// the module docs for the tail-reassignment trick).  Keys move
    /// incrementally — the caller publishes the new epoch and lets the
    /// migration planner compute the delta, exactly like a scale event.
    pub fn set_weight(&mut self, shard: u32, w: u32) -> Result<(), String> {
        let s = shard as usize;
        if s >= self.weights.len() {
            return Err(format!("shard {shard} out of range (n={})", self.weights.len()));
        }
        if w == 0 {
            return Err("weight must be >= 1 (remove the shard instead)".to_string());
        }
        if !self.failed.is_empty() {
            return Err("cluster is degraded; restore failed shards before reweighting".to_string());
        }
        if !self.inner.lifo_ready() {
            return Err("wrapped engine is not LIFO-ready".to_string());
        }
        let cur = self.weights[s];
        if w > cur {
            for _ in cur..w {
                self.grow_vbucket(shard);
            }
        } else {
            for _ in w..cur {
                self.shed_vbucket(shard);
            }
        }
        self.weights[s] = w;
        Ok(())
    }

    /// Append one virtual bucket at the engine tail, owned by `shard`.
    fn grow_vbucket(&mut self, shard: u32) {
        let v = self.inner.add_bucket();
        assert_eq!(v as usize, self.owner.len(), "inner engine must grow at the tail");
        self.owner.push(shard);
    }

    /// Remove one of `shard`'s virtual buckets via the engine tail: if
    /// the tail belongs to another shard, remove it anyway and hand one
    /// of `shard`'s virtual buckets over in exchange (net counts: the
    /// other shard unchanged, `shard` down one).
    fn shed_vbucket(&mut self, shard: u32) {
        let tail = (self.owner.len() - 1) as u32;
        let tail_owner = self.owner[tail as usize];
        let removed = self.inner.remove_bucket();
        assert_eq!(removed, tail, "inner engine must shrink at the tail");
        self.owner.pop();
        if tail_owner != shard {
            // Highest-id virtual bucket of `shard` changes hands, so
            // repeated sheds keep the survivor's holdings tail-dense.
            let v = self
                .owner
                .iter()
                .rposition(|&o| o == shard)
                .expect("shard with positive weight owns a virtual bucket");
            self.owner[v] = tail_owner;
        }
    }

    /// `true` when the last shard's virtual buckets are exactly the
    /// engine tail — i.e. `remove_bucket` needs no reassignment and
    /// relocates only the retiring shard's keys.
    fn tail_aligned(&self) -> bool {
        let Some(&w) = self.weights.last() else { return true };
        let s = (self.weights.len() - 1) as u32;
        self.owner[self.owner.len() - w as usize..].iter().all(|&o| o == s)
    }
}

impl ConsistentHasher for Weighted {
    fn name(&self) -> &'static str {
        "weighted"
    }

    fn len(&self) -> u32 {
        (self.weights.len() - self.failed.len()) as u32
    }

    fn bucket(&self, digest: u64) -> u32 {
        self.owner[self.inner.bucket(digest) as usize]
    }

    fn bucket_batch(&self, digests: &[u64], out: &mut [u32]) {
        // One batched pass through the inner kernel with `out` doubling
        // as the virtual-bucket buffer, then the owner map applied per
        // lane in place — no intermediate allocation, so the router's
        // warm scratch column stays zero-alloc through the adapter.
        self.inner.bucket_batch(digests, out);
        for v in out.iter_mut() {
            *v = self.owner[*v as usize];
        }
    }

    fn add_bucket(&mut self) -> u32 {
        let s = self.weights.len() as u32;
        for _ in 0..self.default_weight {
            self.grow_vbucket(s);
        }
        self.weights.push(self.default_weight);
        s
    }

    fn remove_bucket(&mut self) -> u32 {
        assert!(self.weights.len() > 1, "weighted: cluster would become empty");
        assert!(self.failed.is_empty(), "weighted: cannot shrink while degraded");
        let s = (self.weights.len() - 1) as u32;
        for _ in 0..self.weights[s as usize] {
            self.shed_vbucket(s);
        }
        self.weights.pop();
        s
    }

    fn fork(&self) -> Box<dyn ConsistentHasher> {
        Box::new(Weighted {
            inner: self.inner.fork(),
            owner: self.owner.clone(),
            weights: self.weights.clone(),
            default_weight: self.default_weight,
            failed: self.failed.clone(),
        })
    }

    fn minimal_disruption(&self) -> bool {
        // A shrink relocates only the retiring shard's keys iff the
        // engine does AND no reassignment is needed (the retiring
        // shard's virtual buckets sit exactly at the engine tail).
        self.inner.minimal_disruption() && self.tail_aligned()
    }

    fn max_buckets(&self) -> Option<u32> {
        // Engine headroom in virtual buckets, divided by the join weight.
        self.inner.max_buckets().map(|cap| {
            let headroom = cap.saturating_sub(self.owner.len() as u32) / self.default_weight;
            self.weights.len() as u32 + headroom
        })
    }

    fn lifo_ready(&self) -> bool {
        self.failed.is_empty() && self.inner.lifo_ready()
    }

    fn grow_ready(&self) -> Result<(), String> {
        if !self.failed.is_empty() {
            return Err("weighted: restore failed shards before scaling".to_string());
        }
        self.inner.grow_ready()
    }

    fn shrink_ready(&self) -> Result<(), String> {
        if !self.failed.is_empty() {
            return Err("weighted: restore failed shards before scaling".to_string());
        }
        self.inner.shrink_ready()
    }

    fn as_fault_tolerant(&self) -> Option<&dyn FaultTolerant> {
        self.inner.as_fault_tolerant().map(|_| self as &dyn FaultTolerant)
    }

    fn as_fault_tolerant_mut(&mut self) -> Option<&mut dyn FaultTolerant> {
        if self.inner.as_fault_tolerant().is_some() {
            Some(self as &mut dyn FaultTolerant)
        } else {
            None
        }
    }

    fn as_weighted(&self) -> Option<&Weighted> {
        Some(self)
    }

    fn as_weighted_mut(&mut self) -> Option<&mut Weighted> {
        Some(self)
    }
}

impl FaultTolerant for Weighted {
    fn remove_arbitrary(&mut self, b: u32) {
        assert!((b as usize) < self.weights.len(), "weighted: shard {b} out of range");
        assert!(self.is_working(b), "weighted: shard {b} already failed");
        let vbs: Vec<u32> = (0..self.owner.len() as u32)
            .filter(|&v| self.owner[v as usize] == b)
            .collect();
        let ft = self
            .inner
            .as_fault_tolerant_mut()
            .expect("as_fault_tolerant gated on the inner engine");
        for &v in &vbs {
            ft.remove_arbitrary(v);
        }
        self.failed.push((b, vbs));
    }

    fn restore(&mut self, b: u32) {
        let idx = self
            .failed
            .iter()
            .rposition(|(s, _)| *s == b)
            .expect("weighted: restore of a working shard");
        let (_, vbs) = self.failed.remove(idx);
        let ft = self
            .inner
            .as_fault_tolerant_mut()
            .expect("as_fault_tolerant gated on the inner engine");
        for &v in vbs.iter().rev() {
            ft.restore(v);
        }
    }

    fn is_working(&self, b: u32) -> bool {
        (b as usize) < self.weights.len() && self.failed.iter().all(|(s, _)| *s != b)
    }

    fn restore_blocked(&self, b: u32) -> Option<String> {
        let idx = self.failed.iter().rposition(|(s, _)| *s == b)?;
        let ft = self.inner.as_fault_tolerant()?;
        // The shard's virtual buckets come back in reverse removal
        // order, starting with its most recently removed one; if the
        // engine blocks that (anchor's global reverse-removal rule), the
        // whole shard restore is blocked until the later failure clears.
        let first = *self.failed[idx].1.last()?;
        ft.restore_blocked(first).map(|_| {
            let (s, _) = self.failed.last().expect("blocked restore implies a later failure");
            format!("engine restores in reverse removal order; restore shard {s} first")
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::ALL_ALGORITHMS;
    use crate::hashing::SplitMix64Rng;

    fn digests(k: usize) -> Vec<u64> {
        let mut rng = SplitMix64Rng::new(0xBEEF);
        (0..k).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn uniform_weight_is_placement_identical_to_bare_engine() {
        for name in ALL_ALGORITHMS {
            let bare = by_name(name, 9).unwrap();
            let wrapped = Weighted::uniform(name, 9).unwrap();
            assert_eq!(wrapped.len(), 9, "{name}");
            for d in digests(5_000) {
                assert_eq!(wrapped.bucket(d), bare.bucket(d), "{name}: digest {d:#x}");
            }
        }
    }

    #[test]
    fn two_to_one_weights_carry_twice_the_keys() {
        // 4 shards at 2:1:1:1 — shard 0 must take ~2/5 of the keyspace.
        let w = Weighted::new("binomial", &[2, 1, 1, 1], 1).unwrap();
        assert_eq!(w.len(), 4);
        assert_eq!(w.virtual_buckets(), 5);
        let ds = digests(100_000);
        let mut counts = [0u64; 4];
        for &d in &ds {
            counts[w.bucket(d) as usize] += 1;
        }
        let f0 = counts[0] as f64 / ds.len() as f64;
        assert!((f0 - 0.4).abs() < 0.02, "weight-2 shard got {f0} of the keys");
        for (s, &c) in counts.iter().enumerate().skip(1) {
            let f = c as f64 / ds.len() as f64;
            assert!((f - 0.2).abs() < 0.02, "weight-1 shard {s} got {f}");
        }
    }

    #[test]
    fn bucket_batch_applies_owner_map_per_lane() {
        // The wrapper must compose with the inner batched kernel: one
        // inner `bucket_batch` call, then the owner map in place.
        let w = Weighted::new("binomial", &[2, 1, 3, 1], 1).unwrap();
        let ds = digests(1_003); // full LANES chunks plus a scalar tail
        let mut out = vec![u32::MAX; ds.len()];
        w.bucket_batch(&ds, &mut out);
        for (d, got) in ds.iter().zip(&out) {
            assert_eq!(*got, w.bucket(*d), "digest {d:#x}");
        }
    }

    #[test]
    fn scale_cycle_preserves_lifo_contract() {
        let mut w = Weighted::new("memento", &[2, 1], 3).unwrap();
        assert_eq!(w.add_bucket(), 2, "new shard id is the frontier");
        assert_eq!(w.len(), 3);
        assert_eq!(w.weights(), &[2, 1, 3]);
        assert_eq!(w.virtual_buckets(), 6);
        // The joiner's virtual buckets sit at the tail, so the shrink is
        // minimally disruptive and retires exactly that shard.
        assert!(w.minimal_disruption());
        assert_eq!(w.remove_bucket(), 2);
        assert_eq!(w.weights(), &[2, 1]);
        assert_eq!(w.virtual_buckets(), 3);
    }

    #[test]
    fn weight_changes_move_bounded_key_share() {
        let mut w = Weighted::new("binomial", &[1, 1, 1, 1], 1).unwrap();
        let ds = digests(50_000);
        let before: Vec<u32> = ds.iter().map(|&d| w.bucket(d)).collect();
        w.set_weight(1, 3).unwrap();
        assert_eq!(w.weights(), &[1, 3, 1, 1]);
        let after: Vec<u32> = ds.iter().map(|&d| w.bucket(d)).collect();
        // Monotone growth: every moved key moved *onto* shard 1.
        let moved = before
            .iter()
            .zip(&after)
            .filter(|(b, a)| b != a)
            .inspect(|(_, a)| assert_eq!(**a, 1, "growth moved a key off the grown shard"))
            .count();
        // Shard 1 went from 1/4 to 3/6 of the keyspace: ~1/3 of keys move.
        let frac = moved as f64 / ds.len() as f64;
        assert!(frac > 0.15 && frac < 0.45, "moved fraction {frac}");
        // And shrinking back moves only a bounded share (~2 virtual
        // buckets' worth per step via the tail trick).
        let before: Vec<u32> = ds.iter().map(|&d| w.bucket(d)).collect();
        w.set_weight(1, 1).unwrap();
        let after: Vec<u32> = ds.iter().map(|&d| w.bucket(d)).collect();
        let moved = before.iter().zip(&after).filter(|(b, a)| b != a).count();
        let frac = moved as f64 / ds.len() as f64;
        assert!(frac < 0.7, "shrink moved {frac} of the keyset");
        assert_eq!(w.weights(), &[1, 1, 1, 1]);
        assert_eq!(w.virtual_buckets(), 4);
    }

    #[test]
    fn set_weight_validation() {
        let mut w = Weighted::new("memento", &[1, 1], 1).unwrap();
        assert!(w.set_weight(5, 2).is_err(), "out-of-range shard");
        assert!(w.set_weight(0, 0).is_err(), "zero weight");
        w.remove_arbitrary(1);
        assert!(w.set_weight(0, 2).is_err(), "reweight while degraded");
        w.restore(1);
        assert!(w.set_weight(0, 2).is_ok());
    }

    #[test]
    fn fork_is_independent_and_identical() {
        let mut w = Weighted::new("memento", &[2, 1, 1], 2).unwrap();
        let fork = w.fork();
        let ds = digests(10_000);
        for &d in &ds {
            assert_eq!(w.bucket(d), fork.bucket(d));
        }
        // Mutating the original never affects the fork.
        w.set_weight(0, 4).unwrap();
        let wref: &dyn ConsistentHasher = &w;
        assert!(ds.iter().any(|&d| wref.bucket(d) != fork.bucket(d)));
        assert_eq!(fork.as_weighted().unwrap().weights(), &[2, 1, 1]);
    }

    #[test]
    fn failover_removes_and_restores_whole_shards() {
        let mut w = Weighted::new("memento", &[2, 1, 2], 1).unwrap();
        let ds = digests(20_000);
        let before: Vec<u32> = ds.iter().map(|&d| w.bucket(d)).collect();
        w.remove_arbitrary(0);
        assert_eq!(w.len(), 2);
        assert!(!w.is_working(0) && w.is_working(1) && w.is_working(2));
        assert!(w.grow_ready().is_err() && w.shrink_ready().is_err());
        for (&d, &b) in ds.iter().zip(&before) {
            let now = w.bucket(d);
            assert_ne!(now, 0, "digest {d:#x} routed to the failed shard");
            if b != 0 {
                assert_eq!(now, b, "survivor key moved on an unrelated failure");
            }
        }
        w.restore(0);
        assert_eq!(w.len(), 3);
        let after: Vec<u32> = ds.iter().map(|&d| w.bucket(d)).collect();
        assert_eq!(before, after, "restore must return to the pre-failure placement");
    }

    #[test]
    fn anchor_ordering_surfaces_at_shard_granularity() {
        let mut w = Weighted::new("anchor", &[1, 2, 1, 1], 1).unwrap();
        w.remove_arbitrary(1);
        w.remove_arbitrary(3);
        let msg = w.restore_blocked(1).expect("anchor blocks out-of-order restore");
        assert!(msg.contains('3'), "{msg}");
        assert!(w.restore_blocked(3).is_none());
        w.restore(3);
        assert!(w.restore_blocked(1).is_none());
        w.restore(1);
        assert_eq!(w.len(), 4);
    }

    #[test]
    fn weighted_surfaces_through_type_erasure() {
        let w = Weighted::new("binomial", &[1, 2], 1).unwrap();
        let boxed: Box<dyn ConsistentHasher> = Box::new(w);
        let fork = boxed.fork();
        assert_eq!(fork.name(), "weighted");
        assert_eq!(fork.as_weighted().unwrap().weights(), &[1, 2]);
        // Bare engines answer None.
        assert!(by_name("binomial", 4).unwrap().as_weighted().is_none());
    }
}
