//! Consistent-hashing algorithms: the paper's contribution plus every
//! baseline it is evaluated against (§6) and the broader suite from the
//! authors' survey \[3\].
//!
//! All algorithms implement [`ConsistentHasher`]: a mapping from a u64 key
//! digest to a bucket in `[0, n)` that satisfies, under LIFO cluster
//! changes, the three consistency properties of §3:
//!
//! * **balance** — ~k/n keys per bucket;
//! * **minimal disruption** — removing a bucket relocates only its keys;
//! * **monotonicity** — adding a bucket only moves keys onto it.
//!
//! Fidelity levels (see DESIGN.md §3): `binomial` is an exact
//! implementation of the paper (golden-pinned against the Python spec);
//! `jump`, `anchor`, `ring`, `rendezvous`, `maglev`, `multiprobe`, `dx`
//! follow their published pseudocode; `powerch`, `fliphash`, `jumpback`
//! are documented reconstructions matching the published structure,
//! arithmetic class (float vs integer) and complexity — their exact
//! constants were not recoverable, which affects absolute (not relative)
//! timings.
//!
//! Every engine is **forkable**: [`ConsistentHasher::fork`] returns a
//! deep, independently-mutable clone of the placement state.  The
//! epoch-snapshot scaling path builds the next topology's engine by
//! forking the live one and applying `add_bucket`/`remove_bucket`, so
//! stateful engines (anchor's working/removed sets, dx's node-state
//! array, memento's replacement table) scale exactly like the stateless
//! family — no engine is ever reconstructed from its name.

pub mod anchor;
pub mod binomial;
pub mod dx;
pub mod fliphash;
pub mod jump;
pub mod jumpback;
pub mod maglev;
pub mod memento;
pub mod modulo;
pub mod multiprobe;
pub mod powerch;
pub mod rendezvous;
pub mod ring;

use crate::hashing::xxhash64;

/// A consistent mapping from key digests to buckets `[0, n)` under LIFO
/// (last-in-first-out) cluster resizing.
pub trait ConsistentHasher: Send + Sync {
    /// Algorithm name (stable identifier used by configs and benches).
    fn name(&self) -> &'static str;

    /// Current number of working buckets `n`.
    fn len(&self) -> u32;

    /// `true` when no bucket is available.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Map a key digest to a bucket in `[0, n)`.
    fn bucket(&self, digest: u64) -> u32;

    /// Add the next bucket (id `n`), returning its id. LIFO order.
    fn add_bucket(&mut self) -> u32;

    /// Remove the last-added bucket (id `n-1`), returning its id.
    ///
    /// # Panics
    /// Panics if the cluster would become empty.
    fn remove_bucket(&mut self) -> u32;

    /// Deep, independently-mutable clone of this engine's placement
    /// state.
    ///
    /// A fork maps every digest exactly as its parent does at the moment
    /// of the fork, and mutating either side (`add_bucket`,
    /// `remove_bucket`, arbitrary removals on [`FaultTolerant`] engines)
    /// never affects the other.  The router's scaling path relies on
    /// this: each epoch's engine is a fork of the previous epoch's, so
    /// stateful engines carry their full state (anchor's removal
    /// metadata, dx's node-state array, memento's failure table) across
    /// topology changes.
    fn fork(&self) -> Box<dyn ConsistentHasher>;

    /// `true` when LIFO removal relocates only the removed bucket's keys
    /// (the paper's minimal-disruption property, §3).
    ///
    /// Engines without the exact guarantee — maglev's table rebuild is
    /// only approximately minimal, and the modulo anti-baseline
    /// reshuffles ~half the keyset — return `false`, which makes the
    /// migration planner scan every shard on scale-down instead of only
    /// the retiring one.
    fn minimal_disruption(&self) -> bool {
        true
    }

    /// Hard upper bound on `len()` for engines whose state pre-allocates
    /// a fixed slot range (anchor's anchor set, dx's NSArray); `None`
    /// when the engine can grow without bound.
    ///
    /// The router checks this before a scale-up so a full engine is
    /// rejected cleanly instead of `add_bucket` panicking mid-change.
    /// (Named distinctly from the engines' inherent `capacity()`
    /// accessors, which report raw slot counts.)
    fn max_buckets(&self) -> Option<u32> {
        None
    }

    /// `true` when the engine can scale at the LIFO tail right now:
    /// `add_bucket` will assign bucket `n` and `remove_bucket` will
    /// retire bucket `n-1`.
    ///
    /// Engines with outstanding arbitrary removals ([`FaultTolerant`])
    /// return `false` — their bucket range has holes, so LIFO scaling is
    /// undefined (and may panic) until every failed bucket is restored.
    /// The router rejects scale ops in that state instead of mutating a
    /// fork that would misroute or unwind mid-change.
    fn lifo_ready(&self) -> bool {
        true
    }

    /// Convenience: hash a byte-string key and map it.
    fn bucket_for_key(&self, key: &[u8]) -> u32 {
        self.bucket(xxhash64(key, 0))
    }
}

/// Algorithms that natively support removing an *arbitrary* bucket (not
/// just the last-added one) with minimal disruption.
pub trait FaultTolerant: ConsistentHasher {
    /// Remove bucket `b` (which must be working).
    fn remove_arbitrary(&mut self, b: u32);

    /// Restore a previously removed bucket `b`.
    fn restore(&mut self, b: u32);

    /// Is bucket `b` currently working?
    fn is_working(&self, b: u32) -> bool;
}

/// Names of every registered algorithm, in benchmark display order.
pub const ALL_ALGORITHMS: &[&str] = &[
    "binomial",
    "jumpback",
    "powerch",
    "fliphash",
    "jump",
    "anchor",
    "dx",
    "memento",
    "maglev",
    "multiprobe",
    "ring",
    "rendezvous",
];

/// The four constant-time algorithms compared in the paper's §6.
pub const PAPER_ALGORITHMS: &[&str] = &["binomial", "jumpback", "powerch", "fliphash"];

/// Non-consistent anti-baseline (not in [`ALL_ALGORITHMS`]: it
/// deliberately violates monotonicity/minimal disruption; the disruption
/// bench includes it to quantify what consistency buys).
pub const ANTI_BASELINE: &str = "modulo";

/// Construct an algorithm by name with `n` initial buckets.
///
/// Returns `None` for unknown names; see [`ALL_ALGORITHMS`].
pub fn by_name(name: &str, n: u32) -> Option<Box<dyn ConsistentHasher>> {
    Some(match name {
        "binomial" => Box::new(binomial::BinomialHash::new(n)),
        "jump" => Box::new(jump::JumpHash::new(n)),
        "jumpback" => Box::new(jumpback::JumpBackHash::new(n)),
        "powerch" => Box::new(powerch::PowerCh::new(n)),
        "fliphash" => Box::new(fliphash::FlipHash::new(n)),
        "anchor" => {
            // Generous default headroom: the anchor set bounds the maximum
            // cluster size, and growth past it is a rebuild.
            let capacity = (n.next_power_of_two() * 2).max(64);
            Box::new(anchor::AnchorHash::with_capacity(n, capacity))
        }
        "dx" => Box::new(dx::DxHash::new(n)),
        "memento" => Box::new(memento::MementoHash::new(n)),
        "modulo" => Box::new(modulo::ModuloHash::new(n)),
        "ring" => Box::new(ring::HashRing::new(n, ring::DEFAULT_VNODES)),
        "rendezvous" => Box::new(rendezvous::Rendezvous::new(n)),
        "maglev" => Box::new(maglev::Maglev::new(n)),
        "multiprobe" => Box::new(multiprobe::MultiProbe::new(n, multiprobe::DEFAULT_PROBES)),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all() {
        for name in ALL_ALGORITHMS {
            let h = by_name(name, 7).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(h.len(), 7, "{name}");
            assert_eq!(h.name(), *name);
        }
        assert!(by_name("nope", 3).is_none());
    }

    // The fork contract (identical mapping at the fork point, full
    // independence afterward, stateful-state carry-over) is pinned for
    // every engine by `rust/tests/engine_fork.rs`.

    #[test]
    fn bucket_for_key_matches_digest_path() {
        let h = by_name("binomial", 12).unwrap();
        let key = b"object/alpha";
        assert_eq!(h.bucket_for_key(key), h.bucket(xxhash64(key, 0)));
    }
}
