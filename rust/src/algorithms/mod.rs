//! Consistent-hashing algorithms: the paper's contribution plus every
//! baseline it is evaluated against (§6) and the broader suite from the
//! authors' survey \[3\].
//!
//! All algorithms implement [`ConsistentHasher`]: a mapping from a u64 key
//! digest to a bucket in `[0, n)` that satisfies, under LIFO cluster
//! changes, the three consistency properties of §3:
//!
//! * **balance** — ~k/n keys per bucket;
//! * **minimal disruption** — removing a bucket relocates only its keys;
//! * **monotonicity** — adding a bucket only moves keys onto it.
//!
//! Fidelity levels (see DESIGN.md §3): `binomial` is an exact
//! implementation of the paper (golden-pinned against the Python spec);
//! `jump`, `anchor`, `ring`, `rendezvous`, `maglev`, `multiprobe`, `dx`
//! follow their published pseudocode; `powerch`, `fliphash`, `jumpback`
//! are documented reconstructions matching the published structure,
//! arithmetic class (float vs integer) and complexity — their exact
//! constants were not recoverable, which affects absolute (not relative)
//! timings.

pub mod anchor;
pub mod binomial;
pub mod dx;
pub mod fliphash;
pub mod jump;
pub mod jumpback;
pub mod maglev;
pub mod memento;
pub mod modulo;
pub mod multiprobe;
pub mod powerch;
pub mod rendezvous;
pub mod ring;

use crate::hashing::xxhash64;

/// A consistent mapping from key digests to buckets `[0, n)` under LIFO
/// (last-in-first-out) cluster resizing.
pub trait ConsistentHasher: Send + Sync {
    /// Algorithm name (stable identifier used by configs and benches).
    fn name(&self) -> &'static str;

    /// Current number of working buckets `n`.
    fn len(&self) -> u32;

    /// `true` when no bucket is available.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Map a key digest to a bucket in `[0, n)`.
    fn bucket(&self, digest: u64) -> u32;

    /// Add the next bucket (id `n`), returning its id. LIFO order.
    fn add_bucket(&mut self) -> u32;

    /// Remove the last-added bucket (id `n-1`), returning its id.
    ///
    /// # Panics
    /// Panics if the cluster would become empty.
    fn remove_bucket(&mut self) -> u32;

    /// Convenience: hash a byte-string key and map it.
    fn bucket_for_key(&self, key: &[u8]) -> u32 {
        self.bucket(xxhash64(key, 0))
    }
}

/// Algorithms that natively support removing an *arbitrary* bucket (not
/// just the last-added one) with minimal disruption.
pub trait FaultTolerant: ConsistentHasher {
    /// Remove bucket `b` (which must be working).
    fn remove_arbitrary(&mut self, b: u32);

    /// Restore a previously removed bucket `b`.
    fn restore(&mut self, b: u32);

    /// Is bucket `b` currently working?
    fn is_working(&self, b: u32) -> bool;
}

/// Names of every registered algorithm, in benchmark display order.
pub const ALL_ALGORITHMS: &[&str] = &[
    "binomial",
    "jumpback",
    "powerch",
    "fliphash",
    "jump",
    "anchor",
    "dx",
    "memento",
    "maglev",
    "multiprobe",
    "ring",
    "rendezvous",
];

/// The four constant-time algorithms compared in the paper's §6.
pub const PAPER_ALGORITHMS: &[&str] = &["binomial", "jumpback", "powerch", "fliphash"];

/// Non-consistent anti-baseline (not in [`ALL_ALGORITHMS`]: it
/// deliberately violates monotonicity/minimal disruption; the disruption
/// bench includes it to quantify what consistency buys).
pub const ANTI_BASELINE: &str = "modulo";

/// Construct an algorithm by name with `n` initial buckets.
///
/// Returns `None` for unknown names; see [`ALL_ALGORITHMS`].
pub fn by_name(name: &str, n: u32) -> Option<Box<dyn ConsistentHasher>> {
    Some(match name {
        "binomial" => Box::new(binomial::BinomialHash::new(n)),
        "jump" => Box::new(jump::JumpHash::new(n)),
        "jumpback" => Box::new(jumpback::JumpBackHash::new(n)),
        "powerch" => Box::new(powerch::PowerCh::new(n)),
        "fliphash" => Box::new(fliphash::FlipHash::new(n)),
        "anchor" => {
            // Generous default headroom: the anchor set bounds the maximum
            // cluster size, and growth past it is a rebuild.
            let capacity = (n.next_power_of_two() * 2).max(64);
            Box::new(anchor::AnchorHash::with_capacity(n, capacity))
        }
        "dx" => Box::new(dx::DxHash::new(n)),
        "memento" => Box::new(memento::MementoHash::new(n)),
        "modulo" => Box::new(modulo::ModuloHash::new(n)),
        "ring" => Box::new(ring::HashRing::new(n, ring::DEFAULT_VNODES)),
        "rendezvous" => Box::new(rendezvous::Rendezvous::new(n)),
        "maglev" => Box::new(maglev::Maglev::new(n)),
        "multiprobe" => Box::new(multiprobe::MultiProbe::new(n, multiprobe::DEFAULT_PROBES)),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all() {
        for name in ALL_ALGORITHMS {
            let h = by_name(name, 7).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(h.len(), 7, "{name}");
            assert_eq!(h.name(), *name);
        }
        assert!(by_name("nope", 3).is_none());
    }

    #[test]
    fn bucket_for_key_matches_digest_path() {
        let h = by_name("binomial", 12).unwrap();
        let key = b"object/alpha";
        assert_eq!(h.bucket_for_key(key), h.bucket(xxhash64(key, 0)));
    }
}
