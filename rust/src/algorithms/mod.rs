//! Consistent-hashing algorithms: the paper's contribution plus every
//! baseline it is evaluated against (§6) and the broader suite from the
//! authors' survey \[3\].
//!
//! All algorithms implement [`ConsistentHasher`]: a mapping from a u64 key
//! digest to a bucket in `[0, n)` that satisfies, under LIFO cluster
//! changes, the three consistency properties of §3:
//!
//! * **balance** — ~k/n keys per bucket;
//! * **minimal disruption** — removing a bucket relocates only its keys;
//! * **monotonicity** — adding a bucket only moves keys onto it.
//!
//! Fidelity levels: `binomial` is an exact
//! implementation of the paper (golden-pinned against the Python spec);
//! `jump`, `anchor`, `ring`, `rendezvous`, `maglev`, `multiprobe`, `dx`
//! follow their published pseudocode; `powerch`, `fliphash`, `jumpback`
//! are documented reconstructions matching the published structure,
//! arithmetic class (float vs integer) and complexity — their exact
//! constants were not recoverable, which affects absolute (not relative)
//! timings.
//!
//! Every engine is **forkable**: [`ConsistentHasher::fork`] returns a
//! deep, independently-mutable clone of the placement state.  The
//! epoch-snapshot scaling path builds the next topology's engine by
//! forking the live one and applying `add_bucket`/`remove_bucket`, so
//! stateful engines (anchor's working/removed sets, dx's node-state
//! array, memento's replacement table) scale exactly like the stateless
//! family — no engine is ever reconstructed from its name.
//!
//! ## Failover: the [`FaultTolerant`] surface through `fork`
//!
//! `fork` returns `Box<dyn ConsistentHasher>`, which would sever the
//! arbitrary-removal interface of the three fault-tolerant engines
//! (anchor, dx, memento).  [`ConsistentHasher::as_fault_tolerant`] /
//! [`as_fault_tolerant_mut`](ConsistentHasher::as_fault_tolerant_mut)
//! re-expose it: the router forks the live engine, downcasts the fork,
//! applies [`FaultTolerant::remove_arbitrary`], and publishes the result
//! as a *degraded* epoch — O(1) engine work, no key scan (minimal
//! disruption guarantees only the dead bucket's keys moved, and their
//! data is on the dead shard anyway).
//!
//! The failover lifecycle an engine sees is **steady → degraded →
//! restored-or-rescaled**:
//!
//! * *degraded*: one or more arbitrary removals outstanding.  Lookups
//!   route around the holes; bucket ids stay stable (no renumbering).
//! * *restored*: [`FaultTolerant::restore`] re-fills a hole.  Engines may
//!   constrain the order ([`FaultTolerant::restore_blocked`] — anchor
//!   restores in reverse removal order); the caller asks first instead of
//!   hitting an assert.
//! * *rescaled*: LIFO scaling while degraded is per-engine
//!   ([`ConsistentHasher::grow_ready`] /
//!   [`shrink_ready`](ConsistentHasher::shrink_ready)): dx's add
//!   frontier is disjoint from its holes, so it composes; anchor's
//!   `add_bucket` would *restore* the most recent failure instead of
//!   growing, and memento's asserts fire — both report
//!   restore-then-resize, and the router fails fast with that reason.

pub mod anchor;
pub mod binomial;
pub mod dx;
pub mod fliphash;
pub mod jump;
pub mod jumpback;
pub mod maglev;
pub mod memento;
pub mod modulo;
pub mod multiprobe;
pub mod powerch;
pub mod rendezvous;
pub mod ring;
pub mod weighted;

use crate::hashing::xxhash64;

/// A consistent mapping from key digests to buckets `[0, n)` under LIFO
/// (last-in-first-out) cluster resizing.
pub trait ConsistentHasher: Send + Sync {
    /// Algorithm name (stable identifier used by configs and benches).
    fn name(&self) -> &'static str;

    /// Current number of working buckets `n`.
    fn len(&self) -> u32;

    /// `true` when no bucket is available.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Map a key digest to a bucket in `[0, n)`.
    fn bucket(&self, digest: u64) -> u32;

    /// Map a batch of key digests to buckets, writing `out[i] =
    /// bucket(digests[i])` for every `i`.
    ///
    /// The default is the scalar loop, so every engine supports batched
    /// placement with identical results.  Engines whose lookup is pure
    /// branch-light integer work override it with a lane-parallel kernel
    /// ([`binomial::lookup_batch`] runs 8 independent dependency chains
    /// per chunk); wrappers forward to the inner kernel and post-process
    /// per lane ([`weighted::Weighted`] applies the owner map in place).
    /// Batch callers (the router's MGET/MPUT placement column, the
    /// migration stripe planner) hold the full digest list up front, so
    /// they call this once instead of `bucket` per key.
    ///
    /// # Panics
    /// Panics if `digests.len() != out.len()`.
    fn bucket_batch(&self, digests: &[u64], out: &mut [u32]) {
        assert_eq!(digests.len(), out.len(), "bucket_batch slice length mismatch");
        for (slot, digest) in out.iter_mut().zip(digests) {
            *slot = self.bucket(*digest);
        }
    }

    /// Add the next bucket (id `n`), returning its id. LIFO order.
    fn add_bucket(&mut self) -> u32;

    /// Remove the last-added bucket (id `n-1`), returning its id.
    ///
    /// # Panics
    /// Panics if the cluster would become empty.
    fn remove_bucket(&mut self) -> u32;

    /// Deep, independently-mutable clone of this engine's placement
    /// state.
    ///
    /// A fork maps every digest exactly as its parent does at the moment
    /// of the fork, and mutating either side (`add_bucket`,
    /// `remove_bucket`, arbitrary removals on [`FaultTolerant`] engines)
    /// never affects the other.  The router's scaling path relies on
    /// this: each epoch's engine is a fork of the previous epoch's, so
    /// stateful engines carry their full state (anchor's removal
    /// metadata, dx's node-state array, memento's failure table) across
    /// topology changes.
    fn fork(&self) -> Box<dyn ConsistentHasher>;

    /// `true` when LIFO removal relocates only the removed bucket's keys
    /// (the paper's minimal-disruption property, §3).
    ///
    /// Engines without the exact guarantee — maglev's table rebuild is
    /// only approximately minimal, and the modulo anti-baseline
    /// reshuffles ~half the keyset — return `false`, which makes the
    /// migration planner scan every shard on scale-down instead of only
    /// the retiring one.
    fn minimal_disruption(&self) -> bool {
        true
    }

    /// Hard upper bound on `len()` for engines whose state pre-allocates
    /// a fixed slot range (anchor's anchor set, dx's NSArray); `None`
    /// when the engine can grow without bound.
    ///
    /// The router checks this before a scale-up so a full engine is
    /// rejected cleanly instead of `add_bucket` panicking mid-change.
    /// (Named distinctly from the engines' inherent `capacity()`
    /// accessors, which report raw slot counts.)
    fn max_buckets(&self) -> Option<u32> {
        None
    }

    /// `true` when the engine can scale at the LIFO tail right now:
    /// `add_bucket` will assign bucket `n` and `remove_bucket` will
    /// retire bucket `n-1`.
    ///
    /// Engines with outstanding arbitrary removals ([`FaultTolerant`])
    /// return `false` — their bucket range has holes, so LIFO scaling is
    /// undefined (and may panic) until every failed bucket is restored.
    /// The router rejects scale ops in that state instead of mutating a
    /// fork that would misroute or unwind mid-change.
    fn lifo_ready(&self) -> bool {
        true
    }

    /// `Ok(())` when `add_bucket` will assign a fresh id at the
    /// assignment frontier (one past the highest id ever assigned) right
    /// now; `Err(reason)` naming what blocks growth otherwise — never
    /// panics.
    ///
    /// The default ties growth to [`lifo_ready`](Self::lifo_ready).
    /// Fault-tolerant engines refine it: dx grows at its frontier even
    /// with holes outstanding (growth *composes* with failures), while
    /// anchor's `add_bucket` would restore the most recent failure
    /// instead of growing and memento's would panic — both explain that
    /// failed buckets must be restored first.  Capacity limits are
    /// reported separately via [`max_buckets`](Self::max_buckets).
    fn grow_ready(&self) -> Result<(), String> {
        if self.lifo_ready() {
            Ok(())
        } else {
            Err("outstanding arbitrary removals leave holes in the bucket range; \
                 restore the failed buckets first"
                .to_string())
        }
    }

    /// `Ok(())` when `remove_bucket` will retire the bucket at the
    /// assignment frontier (the highest assigned id) right now;
    /// `Err(reason)` otherwise — never panics.
    ///
    /// Same contract as [`grow_ready`](Self::grow_ready): dx can shrink
    /// while degraded as long as the frontier bucket itself is working;
    /// anchor and memento require all failures restored first.
    fn shrink_ready(&self) -> Result<(), String> {
        if self.lifo_ready() {
            Ok(())
        } else {
            Err("outstanding arbitrary removals leave holes in the bucket range; \
                 restore the failed buckets first"
                .to_string())
        }
    }

    /// This engine's [`FaultTolerant`] surface, if it has one (read-only
    /// view: failed-bucket queries, degraded STATS).
    ///
    /// Default `None`: most engines only support LIFO resizing.  The
    /// fault-tolerant trio (anchor, dx, memento) return `Some(self)`,
    /// which is what lets a `Box<dyn ConsistentHasher>` produced by
    /// [`fork`](Self::fork) keep its failover capability — the router
    /// never needs the concrete type.
    fn as_fault_tolerant(&self) -> Option<&dyn FaultTolerant> {
        None
    }

    /// Mutable access to this engine's [`FaultTolerant`] surface, if it
    /// has one (`remove_arbitrary` / `restore` on a forked engine — the
    /// router's failover publish path).
    fn as_fault_tolerant_mut(&mut self) -> Option<&mut dyn FaultTolerant> {
        None
    }

    /// This engine's weight surface, if it is a [`weighted::Weighted`]
    /// adapter (read-only view: the weight table, virtual-bucket count).
    ///
    /// Default `None`: bare engines have no weights.  Like
    /// [`as_fault_tolerant`](Self::as_fault_tolerant), the hook is what
    /// lets a type-erased [`fork`](Self::fork) keep the surface — the
    /// router's reweight path forks the live engine and downcasts the
    /// fork.
    fn as_weighted(&self) -> Option<&weighted::Weighted> {
        None
    }

    /// Mutable access to the weight surface, if any
    /// ([`weighted::Weighted::set_weight`] on a forked engine — the
    /// router's reweight publish path).
    fn as_weighted_mut(&mut self) -> Option<&mut weighted::Weighted> {
        None
    }

    /// Convenience: hash a byte-string key and map it.
    fn bucket_for_key(&self, key: &[u8]) -> u32 {
        self.bucket(xxhash64(key, 0))
    }
}

/// Algorithms that natively support removing an *arbitrary* bucket (not
/// just the last-added one) with minimal disruption.
///
/// Reached through a trait object via
/// [`ConsistentHasher::as_fault_tolerant`] /
/// [`as_fault_tolerant_mut`](ConsistentHasher::as_fault_tolerant_mut),
/// so a forked engine keeps the surface.
pub trait FaultTolerant: ConsistentHasher {
    /// Remove bucket `b` (which must be working).
    fn remove_arbitrary(&mut self, b: u32);

    /// Restore a previously removed bucket `b`.
    fn restore(&mut self, b: u32);

    /// Is bucket `b` currently working?
    fn is_working(&self, b: u32) -> bool;

    /// `None` when [`restore`](Self::restore)`(b)` is legal right now;
    /// `Some(reason)` otherwise — never panics.
    ///
    /// Engines with ordering constraints refine this: anchor restores in
    /// reverse removal order and names the bucket that must come back
    /// first.  The caller is expected to have checked that `b` is
    /// actually failed; this reports *ordering* blocks only.
    fn restore_blocked(&self, _b: u32) -> Option<String> {
        None
    }
}

/// Names of every registered algorithm, in benchmark display order.
pub const ALL_ALGORITHMS: &[&str] = &[
    "binomial",
    "jumpback",
    "powerch",
    "fliphash",
    "jump",
    "anchor",
    "dx",
    "memento",
    "maglev",
    "multiprobe",
    "ring",
    "rendezvous",
];

/// The four constant-time algorithms compared in the paper's §6.
pub const PAPER_ALGORITHMS: &[&str] = &["binomial", "jumpback", "powerch", "fliphash"];

/// Non-consistent anti-baseline (not in [`ALL_ALGORITHMS`]: it
/// deliberately violates monotonicity/minimal disruption; the disruption
/// bench includes it to quantify what consistency buys).
pub const ANTI_BASELINE: &str = "modulo";

/// Construct an algorithm by name with `n` initial buckets.
///
/// Returns `None` for unknown names; see [`ALL_ALGORITHMS`].
pub fn by_name(name: &str, n: u32) -> Option<Box<dyn ConsistentHasher>> {
    Some(match name {
        "binomial" => Box::new(binomial::BinomialHash::new(n)),
        "jump" => Box::new(jump::JumpHash::new(n)),
        "jumpback" => Box::new(jumpback::JumpBackHash::new(n)),
        "powerch" => Box::new(powerch::PowerCh::new(n)),
        "fliphash" => Box::new(fliphash::FlipHash::new(n)),
        "anchor" => {
            // Generous default headroom: the anchor set bounds the maximum
            // cluster size, and growth past it is a rebuild.
            let capacity = (n.next_power_of_two() * 2).max(64);
            Box::new(anchor::AnchorHash::with_capacity(n, capacity))
        }
        "dx" => Box::new(dx::DxHash::new(n)),
        "memento" => Box::new(memento::MementoHash::new(n)),
        "modulo" => Box::new(modulo::ModuloHash::new(n)),
        "ring" => Box::new(ring::HashRing::new(n, ring::DEFAULT_VNODES)),
        "rendezvous" => Box::new(rendezvous::Rendezvous::new(n)),
        "maglev" => Box::new(maglev::Maglev::new(n)),
        "multiprobe" => Box::new(multiprobe::MultiProbe::new(n, multiprobe::DEFAULT_PROBES)),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all() {
        for name in ALL_ALGORITHMS {
            let h = by_name(name, 7).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(h.len(), 7, "{name}");
            assert_eq!(h.name(), *name);
        }
        assert!(by_name("nope", 3).is_none());
    }

    // The fork contract (identical mapping at the fork point, full
    // independence afterward, stateful-state carry-over) is pinned for
    // every engine by `rust/tests/engine_fork.rs`.

    #[test]
    fn fault_tolerant_surface_survives_fork() {
        const FT: &[&str] = &["anchor", "dx", "memento"];
        for name in ALL_ALGORITHMS {
            let mut h = by_name(name, 8).unwrap();
            let expect_ft = FT.contains(name);
            assert_eq!(h.as_fault_tolerant().is_some(), expect_ft, "{name}");
            assert_eq!(h.as_fault_tolerant_mut().is_some(), expect_ft, "{name}");
            // The downcast must survive the type-erasing fork — that is
            // the whole point of the hook.
            let mut fork = h.fork();
            assert_eq!(fork.as_fault_tolerant().is_some(), expect_ft, "{name}: fork lost it");
            if let Some(ft) = fork.as_fault_tolerant_mut() {
                ft.remove_arbitrary(2);
                assert!(!ft.is_working(2), "{name}: downcast mutation had no effect");
                assert_eq!(fork.len(), 7, "{name}");
            }
        }
    }

    #[test]
    fn healthy_engines_are_scale_ready() {
        for name in ALL_ALGORITHMS {
            let h = by_name(name, 6).unwrap();
            assert!(h.grow_ready().is_ok(), "{name}");
            assert!(h.shrink_ready().is_ok(), "{name}");
        }
    }

    #[test]
    fn bucket_for_key_matches_digest_path() {
        let h = by_name("binomial", 12).unwrap();
        let key = b"object/alpha";
        assert_eq!(h.bucket_for_key(key), h.bucket(xxhash64(key, 0)));
    }

    #[test]
    fn bucket_batch_matches_scalar_for_every_engine() {
        use crate::hashing::SplitMix64Rng;
        let mut rng = SplitMix64Rng::new(0xbbb0);
        let digests: Vec<u64> = (0..257).map(|_| rng.next_u64()).collect();
        let mut out = vec![0u32; digests.len()];
        for name in ALL_ALGORITHMS.iter().chain(std::iter::once(&ANTI_BASELINE)) {
            let h = by_name(name, 11).unwrap();
            h.bucket_batch(&digests, &mut out);
            for (digest, got) in digests.iter().zip(&out) {
                assert_eq!(*got, h.bucket(*digest), "{name} digest {digest:#x}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn bucket_batch_rejects_mismatched_slices() {
        let h = by_name("jump", 4).unwrap();
        let mut out = vec![0u32; 3];
        h.bucket_batch(&[1, 2, 3, 4], &mut out);
    }
}
