//! Per-connection state machine: the readiness-loop counterpart of
//! `proto::serve_framed`, factored so it can be driven deterministically
//! by tests (arbitrary read-chunk and write-chunk boundaries) without a
//! socket in sight.
//!
//! ## How resumption works
//!
//! The blocking parser cannot be suspended mid-`read_line`, so the event
//! path never hands it a partial frame.  [`ConnCore`] buffers raw bytes
//! and uses [`proto::frame_payload_extent`] — which mirrors the parser's
//! own header decisions token for token — to find each frame boundary.
//! Only once a *complete* frame (header line + announced payload) is
//! buffered does it run the unchanged `proto::read_request_ref` over
//! that slice (`&[u8]` is a zero-copy `BufRead`).  Identical parsing by
//! construction; a short read simply leaves the tail buffered until the
//! next readiness wakeup.
//!
//! ```text
//!            bytes in                     complete frame
//!   socket ───────────▶ in_buf ──extent──▶ parse ──▶ handle ──▶ out
//!                         ▲ partial line/payload: wait    │
//!                         └────────── (resume later) ◀────┘ short write:
//!                                                           out_pos marks
//!                                                           resume point
//! ```
//!
//! ## State and error model
//!
//! * Recoverable parse errors (`Wire::Bad`) answer `ERR …` and keep the
//!   connection — same as the blocking loop.
//! * Framing violations (oversized lengths, non-UTF-8 header bytes,
//!   unterminated megabyte lines, payload truncated by EOF) mark the
//!   connection **broken**: buffered responses still flush, then the
//!   server closes — mirroring `serve_framed` returning `Err` after its
//!   final flush.
//! * A final unterminated line at EOF is *parsed*, not dropped, because
//!   the blocking `read_line` returns it without the newline.
//!
//! ## Backpressure rule
//!
//! [`process`](ConnCore::process) refuses to start a new frame while
//! `out_pending() >= OUT_HIGH_WATER`: a slow reader stops consuming our
//! responses, so we stop parsing (and the server stops *reading*) until
//! the flush drains below [`OUT_LOW_WATER`].  Memory per connection is
//! thereby bounded by high-water + one frame's response.
//!
//! ## Memory bounds
//!
//! After every frame the parse scratch is recycled
//! (`RecvBuf::recycle`), and [`compact`](ConnCore::process) both slides
//! consumed bytes out of `in_buf` and shrinks either buffer back to its
//! cap once its contents allow — a single 64 MiB-budget batch must not
//! leave 10k connections holding grown buffers.

use anyhow::Result;

use crate::proto::{self, FrameExtent, RecvBuf, Response, Wire};

use super::Service;

/// Steady-state capacity cap for the input buffer; bigger frames grow it
/// temporarily and `compact` shrinks it back once consumed.
pub const IN_BUF_CAP: usize = 64 << 10;

/// Steady-state capacity cap for the output buffer.
pub const OUT_BUF_CAP: usize = 64 << 10;

/// Stop parsing new frames (and defer read interest) while this many
/// un-flushed response bytes are pending.
pub const OUT_HIGH_WATER: usize = 256 << 10;

/// Resume reads once a deferred connection's pending output drains below
/// this (hysteresis so interest doesn't flap at the boundary).
pub const OUT_LOW_WATER: usize = 64 << 10;

/// Longest header line the event path accepts before declaring the
/// stream unframed.  Must exceed the largest legal header: an `MPUT`
/// line with `MAX_BATCH` maximal keys and lengths is ~2.2 MiB.
pub const MAX_LINE_LEN: usize = 4 << 20;

/// What [`ConnCore::process`] accomplished — drives the server's
/// pump loop (re-process after a flush frees high-water space).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Processed {
    /// No complete frame was consumable (need more input, or deferred by
    /// backpressure, or the connection is broken).
    Idle,
    /// At least one frame was handled; `out` grew.
    Frames,
}

/// Buffered-byte state machine for one framed connection.
#[derive(Debug, Default)]
pub struct ConnCore {
    in_buf: Vec<u8>,
    /// Bytes of `in_buf` before this offset are consumed (compacted lazily).
    in_pos: usize,
    out: Vec<u8>,
    /// Bytes of `out` before this offset are already written to the peer.
    out_pos: usize,
    scratch: RecvBuf,
    /// Framing violation observed: flush what's buffered, then close.
    broken: bool,
    /// EOF seen; an unterminated final line has already been parsed.
    eof: bool,
}

impl ConnCore {
    /// Fresh connection state.
    pub fn new() -> Self {
        Self::default()
    }

    /// Buffer bytes read from the peer.
    pub fn push_input(&mut self, bytes: &[u8]) {
        self.in_buf.extend_from_slice(bytes);
    }

    /// Unconsumed input bytes currently buffered.
    pub fn in_pending(&self) -> usize {
        self.in_buf.len() - self.in_pos
    }

    /// Response bytes not yet written to the peer.
    pub fn out_pending(&self) -> usize {
        self.out.len() - self.out_pos
    }

    /// The un-flushed response bytes (write from the front, then
    /// [`consume_output`](Self::consume_output) what the socket took).
    pub fn output(&self) -> &[u8] {
        &self.out[self.out_pos..]
    }

    /// Record that `n` output bytes reached the peer.
    pub fn consume_output(&mut self, n: usize) {
        self.out_pos += n;
        debug_assert!(self.out_pos <= self.out.len());
        if self.out_pos == self.out.len() {
            self.out.clear();
            self.out_pos = 0;
            if self.out.capacity() > OUT_BUF_CAP {
                self.out.shrink_to(OUT_BUF_CAP);
            }
        }
    }

    /// `true` once a framing violation or handler error has condemned the
    /// connection: flush [`output`](Self::output), then close.
    pub fn is_broken(&self) -> bool {
        self.broken
    }

    /// `true` when the connection has nothing left to do and can close:
    /// broken or at EOF, with all output flushed.
    pub fn is_drained(&self) -> bool {
        (self.broken || self.eof) && self.out_pending() == 0
    }

    /// Reads must stay deferred while pending output sits above the
    /// high-water mark (the server also checks [`OUT_LOW_WATER`] for the
    /// re-enable edge; this is the raw threshold).
    pub fn over_high_water(&self) -> bool {
        self.out_pending() >= OUT_HIGH_WATER
    }

    /// Parse and handle every complete buffered frame, encoding responses
    /// into the out buffer.  Stops early when pending output crosses
    /// [`OUT_HIGH_WATER`] (backpressure) — call again after a flush.
    pub fn process<S: Service>(&mut self, svc: &S, st: &mut S::ConnState) -> Processed {
        let mut did = Processed::Idle;
        while !self.broken {
            if self.out_pending() >= OUT_HIGH_WATER {
                break;
            }
            let avail = &self.in_buf[self.in_pos..];
            if avail.is_empty() {
                break;
            }
            let Some(nl) = avail.iter().position(|&b| b == b'\n') else {
                if avail.len() > MAX_LINE_LEN {
                    // A line this long can never be a legal header; the
                    // blocking path would trip the same length checks.
                    self.broken = true;
                }
                break;
            };
            let line_end = nl + 1;
            let Ok(line) = std::str::from_utf8(&avail[..line_end]) else {
                // read_line fails with InvalidData here: framing error.
                self.broken = true;
                break;
            };
            let total = match proto::frame_payload_extent(line) {
                FrameExtent::LineOnly => line_end,
                FrameExtent::Payload(p) => {
                    let need = line_end + p;
                    if avail.len() < need {
                        break; // mid-payload: resume on the next read
                    }
                    need
                }
                FrameExtent::Oversized => {
                    self.broken = true;
                    break;
                }
            };
            self.handle_frame(svc, st, total);
            if !self.broken {
                did = Processed::Frames;
            }
        }
        self.compact();
        did
    }

    /// Peer sent EOF.  Complete buffered frames were already handled by
    /// [`process`](Self::process); this settles the tail exactly the way
    /// the blocking loop would have:
    ///
    /// * partial payload (or oversized/garbled header) → `read_exact`
    ///   /`read_line` would error → broken;
    /// * an unterminated final line → `read_line` returns it without the
    ///   newline and the parser runs → handle it;
    /// * a *complete* frame still buffered means backpressure deferred it
    ///   — not our call; the server pumps again after flushing.
    pub fn finish_input<S: Service>(&mut self, svc: &S, st: &mut S::ConnState) {
        self.eof = true;
        if self.broken {
            return;
        }
        self.process(svc, st);
        if self.broken || self.in_pending() == 0 {
            return;
        }
        let avail = &self.in_buf[self.in_pos..];
        match avail.iter().position(|&b| b == b'\n') {
            Some(nl) => {
                // A full line is buffered but process() left it: either
                // its payload is truncated by EOF (framing error) or
                // backpressure deferred a complete frame (leave it).
                let Ok(line) = std::str::from_utf8(&avail[..nl + 1]) else {
                    self.broken = true;
                    return;
                };
                match proto::frame_payload_extent(line) {
                    FrameExtent::Payload(p) if avail.len() < nl + 1 + p => self.broken = true,
                    FrameExtent::Oversized => self.broken = true,
                    _ => {}
                }
            }
            None => {
                // Unterminated final line: blocking read_line returns it
                // as-is and the parser runs.  A nonzero announced payload
                // can't follow (EOF), which read_value turns into an
                // error — same outcome, broken.
                let Ok(line) = std::str::from_utf8(avail) else {
                    self.broken = true;
                    return;
                };
                match proto::frame_payload_extent(line) {
                    FrameExtent::LineOnly | FrameExtent::Payload(0) => {
                        let total = avail.len();
                        self.handle_frame(svc, st, total);
                    }
                    FrameExtent::Payload(_) | FrameExtent::Oversized => self.broken = true,
                }
                self.compact();
            }
        }
    }

    /// Parse and dispatch one complete frame of `total` bytes starting at
    /// `in_pos`.  Sets `broken` on parser or handler failure.
    fn handle_frame<S: Service>(&mut self, svc: &S, st: &mut S::ConnState, total: usize) {
        let frame = &self.in_buf[self.in_pos..self.in_pos + total];
        let mut rd: &[u8] = frame;
        let ok = match proto::read_request_ref(&mut rd, &mut self.scratch) {
            Ok(Some(Wire::Req(req))) => svc.handle(st, req, &mut self.out).is_ok(),
            Ok(Some(Wire::Bad(msg))) => {
                proto::encode_response(&mut self.out, &Response::Err(msg)).is_ok()
            }
            // None (empty frame) is unreachable — a frame is ≥ 1 byte —
            // and Err means the extent scan and parser disagreed; both
            // condemn the connection rather than desync the stream.
            Ok(None) | Err(_) => false,
        };
        self.in_pos += total;
        self.scratch.recycle();
        if !ok {
            self.broken = true;
        }
    }

    /// Slide consumed bytes out of `in_buf` and shrink oversized buffers
    /// back toward [`IN_BUF_CAP`] once their contents allow.
    fn compact(&mut self) {
        if self.in_pos == self.in_buf.len() {
            self.in_buf.clear();
            self.in_pos = 0;
        } else if self.in_pos >= IN_BUF_CAP {
            let len = self.in_buf.len();
            self.in_buf.copy_within(self.in_pos.., 0);
            self.in_buf.truncate(len - self.in_pos);
            self.in_pos = 0;
        }
        if self.in_buf.capacity() > IN_BUF_CAP && self.in_buf.len() <= IN_BUF_CAP {
            self.in_buf.shrink_to(IN_BUF_CAP);
        }
    }

    /// Buffer capacities `(in, out)` for tests asserting the
    /// per-connection memory bound.
    pub fn buffer_capacities(&self) -> (usize, usize) {
        (self.in_buf.capacity(), self.out.capacity())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::RequestRef;
    use crate::sync::Mutex;

    /// Echo-ish test service: COUNT answers NUM 1, GET answers NIL,
    /// PUT answers OK and records the value length.
    #[derive(Debug, Default)]
    struct EchoSvc {
        puts: Mutex<Vec<usize>>,
    }

    impl Service for EchoSvc {
        type ConnState = ();
        fn handle(&self, _st: &mut (), req: RequestRef<'_>, out: &mut Vec<u8>) -> Result<()> {
            let resp = match req {
                RequestRef::Count => Response::Num(1),
                RequestRef::Get { .. } => Response::Nil,
                RequestRef::Put { value, .. } => {
                    self.puts.lock().unwrap().push(value.len());
                    Response::Ok
                }
                _ => Response::Ok,
            };
            proto::encode_response(out, &resp)
        }
    }

    fn drive(core: &mut ConnCore, svc: &EchoSvc, bytes: &[u8], chunk: usize) -> Vec<u8> {
        let mut st = ();
        let mut replies = Vec::new();
        for piece in bytes.chunks(chunk.max(1)) {
            core.push_input(piece);
            core.process(svc, &mut st);
            replies.extend_from_slice(core.output());
            let n = core.out_pending();
            core.consume_output(n);
        }
        core.finish_input(svc, &mut st);
        replies.extend_from_slice(core.output());
        let n = core.out_pending();
        core.consume_output(n);
        replies
    }

    #[test]
    fn resumes_across_any_read_boundary() {
        let stream = b"COUNT\nPUT k 5\nhelloGET k\n";
        let want = b"NUM 1\nOK\nNIL\n";
        for chunk in 1..=stream.len() {
            let svc = EchoSvc::default();
            let mut core = ConnCore::new();
            let got = drive(&mut core, &svc, stream, chunk);
            assert_eq!(got, want, "chunk size {chunk}");
            assert!(!core.is_broken());
            assert_eq!(svc.puts.lock().unwrap().as_slice(), &[5]);
        }
    }

    #[test]
    fn truncated_payload_at_eof_breaks_connection() {
        let svc = EchoSvc::default();
        let mut core = ConnCore::new();
        let got = drive(&mut core, &svc, b"COUNT\nPUT k 5\nhel", 3);
        assert_eq!(got, b"NUM 1\n", "responses before the truncation still flush");
        assert!(core.is_broken());
    }

    #[test]
    fn unterminated_final_line_is_parsed_like_read_line() {
        let svc = EchoSvc::default();
        let mut core = ConnCore::new();
        let got = drive(&mut core, &svc, b"GET k\nCOUNT", 4);
        assert_eq!(got, b"NIL\nNUM 1\n");
        assert!(!core.is_broken());
        assert!(core.is_drained());
    }

    #[test]
    fn backpressure_defers_parsing_until_output_drains() {
        let svc = EchoSvc::default();
        let mut core = ConnCore::new();
        let mut st = ();
        // A huge PUT value answered with OK won't cross the high-water
        // mark; fake pressure by writing into out via a big frame burst
        // instead: many COUNTs whose NUM replies accumulate unflushed.
        let burst = "COUNT\n".repeat(OUT_HIGH_WATER / 2);
        core.push_input(burst.as_bytes());
        core.process(&svc, &mut st);
        assert!(core.over_high_water(), "unflushed replies must trip the mark");
        assert!(core.in_pending() > 0, "parsing must stop at the mark");
        let deferred = core.in_pending();
        // Nothing new parses while over the mark…
        assert_eq!(core.process(&svc, &mut st), Processed::Idle);
        assert_eq!(core.in_pending(), deferred);
        // …and a flush releases the logjam.
        while core.out_pending() > 0 || core.in_pending() > 0 {
            let n = core.out_pending().min(8 << 10);
            core.consume_output(n);
            core.process(&svc, &mut st);
        }
        assert!(!core.is_broken());
    }

    #[test]
    fn buffers_shrink_back_after_oversized_traffic() {
        let svc = EchoSvc::default();
        let mut core = ConnCore::new();
        let mut st = ();
        let big = 8 << 20; // 8 MiB value: grows in_buf far past its cap
        let mut stream = format!("PUT big {big}\n").into_bytes();
        stream.resize(stream.len() + big, b'x');
        stream.extend_from_slice(b"GET big\n");
        core.push_input(&stream);
        core.process(&svc, &mut st);
        let n = core.out_pending();
        core.consume_output(n);
        let (in_cap, out_cap) = core.buffer_capacities();
        assert!(in_cap <= 2 * IN_BUF_CAP, "in_buf stuck at {in_cap}");
        assert!(out_cap <= 2 * OUT_BUF_CAP, "out stuck at {out_cap}");
        assert_eq!(svc.puts.lock().unwrap().as_slice(), &[big]);
    }

    #[test]
    fn garbled_header_bytes_break_framing() {
        let svc = EchoSvc::default();
        let mut core = ConnCore::new();
        let mut st = ();
        core.push_input(b"GET \xff\xfe\n");
        core.process(&svc, &mut st);
        assert!(core.is_broken(), "non-UTF-8 header must condemn the stream");
    }
}
