//! Connection serving: one protocol, two server personalities.
//!
//! Router and shard expose the same framed wire protocol (`proto`), so
//! the machinery that moves bytes between sockets and the parser lives
//! here, behind one [`Service`] trait:
//!
//! * **Blocking fallback** — [`serve_blocking`]: thread-per-connection
//!   around `proto::serve_framed`, exactly the pre-event-loop behavior.
//!   Portable, simple, still the reference semantics the event path is
//!   tested against.
//! * **Readiness event server** — [`Server`] with
//!   [`ServeMode::Event`] (Linux): a few shared-nothing event loops on
//!   nonblocking sockets driven by raw `epoll` ([`sys`]), each wrapping
//!   every connection in a [`ConnCore`] state machine.  No async
//!   runtime, no `libc` crate — std plus six declared syscalls.
//!
//! ## Architecture (event mode)
//!
//! ```text
//!   clients ──▶ accept() ──round-robin──▶ HandoffQueue[i] + eventfd[i]
//!   (acceptor thread; max_conns cap)            │
//!                                               ▼
//!                     ┌───────── event loop i (one thread) ─────────┐
//!                     │ epoll_wait ─▶ readable? read→ConnCore.process│
//!                     │              writable/pending? flush partial │
//!                     │ svc.handle() reads SnapshotCell directly —   │
//!                     │ no cross-loop locks on the data path         │
//!                     └─────────────────────────────────────────────┘
//! ```
//!
//! Each loop owns its connections outright (slab of [`ConnCore`]s);
//! the only cross-thread traffic is the accepted-socket handoff
//! (`sync::handoff::HandoffQueue`, wake-suppressed eventfd) and the
//! stop flag.  Request handling inside a loop reads the same lock-free
//! snapshot (`SnapshotCell`) the blocking path reads — fan-in scales
//! with loops, not locks.
//!
//! ## Connection state machine
//!
//! ```text
//!                    ┌──────── READ (EPOLLIN) ────────┐
//!                    ▼                                │
//!   OPEN ──read──▶ buffer ──complete frame──▶ handle ─┴─▶ out-buffer
//!    │                │ partial line/payload             │
//!    │                └────── wait for next read ◀──flush┤ EWOULDBLOCK:
//!    │ EOF/broken                                        │ keep remainder,
//!    ▼                                                   ▼ add EPOLLOUT
//!   DRAIN ──flush rest──▶ CLOSE                    resume on writable
//! ```
//!
//! Interest transitions (level-triggered):
//!
//! | condition                            | EPOLLIN | EPOLLOUT |
//! |--------------------------------------|---------|----------|
//! | steady state                         | yes     | no       |
//! | unflushed output pending             | yes     | yes      |
//! | output ≥ `OUT_HIGH_WATER` (deferred) | **no**  | yes      |
//! | drained below `OUT_LOW_WATER`        | yes     | as needed|
//! | peer EOF / framing error             | no      | if output|
//!
//! **Backpressure rule:** while a connection's un-flushed output is at or
//! above [`conn::OUT_HIGH_WATER`], the loop neither reads its socket nor
//! parses frames it already buffered; both resume once a flush drains
//! the output below [`conn::OUT_LOW_WATER`].  Per-connection memory is
//! thus bounded by high-water + one frame, and
//! [`ConnCore`] shrinks its buffers back to small caps after any
//! oversized burst.
//!
//! ## Shutdown
//!
//! [`ServerHandle::stop`] sets the stop flag, wakes every loop's
//! eventfd, and pokes the acceptor with a throwaway connection.  The
//! acceptor exits immediately; loops stop reading, answer what is
//! already buffered, flush, and close each connection as its output
//! drains — with a bounded grace period before force-close.

use std::io::{self, BufReader};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::thread;

use anyhow::Result;

use crate::metrics::ConnMetrics;
use crate::proto::{self, RequestRef};
use crate::sync::{Arc, AtomicBool, Ordering};

pub mod conn;
#[cfg(target_os = "linux")]
pub mod sys;

pub use conn::ConnCore;

#[cfg(target_os = "linux")]
use crate::sync::handoff::HandoffQueue;

/// A request handler servable by either personality.  Implemented by
/// `router::Router` and `shard::Shard`.
pub trait Service: Send + Sync + 'static {
    /// Per-connection handler scratch (batch digest/selector buffers,
    /// sub-response vector) — state the handler reuses across requests
    /// of one connection but never shares between connections.
    type ConnState: Default + Send + 'static;

    /// Handle one parsed request, encoding the response(s) into `out`.
    /// An `Err` is a framing-level failure: the connection is condemned
    /// (matching the blocking loop, where a handler error aborts
    /// `serve_framed`).
    fn handle(&self, st: &mut Self::ConnState, req: RequestRef<'_>, out: &mut Vec<u8>) -> Result<()>;
}

/// Which serving personality a [`Server`] runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeMode {
    /// Readiness event loops (Linux; silently falls back to
    /// [`ServeMode::Blocking`] elsewhere).
    Event,
    /// Thread-per-connection `serve_framed` fallback.
    Blocking,
}

/// Server configuration (see `config::RouterConfig` for the file-level
/// knobs that feed this).
#[derive(Debug, Clone)]
pub struct ServerOpts {
    /// Serving personality.
    pub mode: ServeMode,
    /// Event-loop thread count; `0` = one per core, capped at 8.
    pub loops: usize,
    /// Accept cap: connections beyond this are dropped (and counted).
    pub max_conns: usize,
    /// Raise the soft `RLIMIT_NOFILE` to the hard limit at startup.
    pub raise_nofile: bool,
    /// Share an existing metrics block (e.g. the router's) instead of a
    /// private one.
    pub metrics: Option<Arc<ConnMetrics>>,
}

impl Default for ServerOpts {
    fn default() -> Self {
        Self { mode: ServeMode::Event, loops: 0, max_conns: 65_536, raise_nofile: true, metrics: None }
    }
}

/// Shared stop/wake state between a running [`Server`] and its
/// [`ServerHandle`]s.
#[derive(Debug)]
struct Ctl {
    stop: AtomicBool,
    addr: SocketAddr,
    #[cfg(target_os = "linux")]
    wakes: Vec<Arc<sys::WakeFd>>,
}

/// Clonable remote control for a running server (see
/// [`ServerHandle::stop`]).
#[derive(Debug, Clone)]
pub struct ServerHandle {
    ctl: Arc<Ctl>,
}

impl ServerHandle {
    /// Request shutdown: the acceptor exits, event loops drain in-flight
    /// connections (answer buffered requests, flush, close) and
    /// [`Server::run`] returns.  Idempotent.
    pub fn stop(&self) {
        // ord: SeqCst — one cold flag checked at loop edges; strongest
        // ordering keeps the shutdown reasoning trivial.
        self.ctl.stop.store(true, Ordering::SeqCst);
        #[cfg(target_os = "linux")]
        for wake in &self.ctl.wakes {
            wake.signal();
        }
        // Unblock a blocking accept() with a throwaway connection; if
        // the listener is already gone there is nothing to unblock.
        let _ = TcpStream::connect(self.ctl.addr);
    }
}

/// A listener bound to a [`Service`], ready to [`run`](Self::run) in
/// either personality.
#[derive(Debug)]
pub struct Server<S: Service> {
    svc: Arc<S>,
    listener: TcpListener,
    opts: ServerOpts,
    nloops: usize,
    ctl: Arc<Ctl>,
    metrics: Arc<ConnMetrics>,
}

impl<S: Service> Server<S> {
    /// Bind `svc` to `listener` with `opts`.
    pub fn new(svc: Arc<S>, listener: TcpListener, opts: ServerOpts) -> Result<Self> {
        let addr = listener.local_addr()?;
        let event = cfg!(target_os = "linux") && opts.mode == ServeMode::Event;
        let nloops = if event {
            match opts.loops {
                0 => thread::available_parallelism().map_or(4, |n| n.get()).clamp(1, 8),
                n => n,
            }
        } else {
            0
        };
        #[cfg(target_os = "linux")]
        let wakes = (0..nloops)
            .map(|_| sys::WakeFd::new().map(Arc::new))
            .collect::<io::Result<Vec<_>>>()?;
        let ctl = Arc::new(Ctl {
            stop: AtomicBool::new(false),
            addr,
            #[cfg(target_os = "linux")]
            wakes,
        });
        let metrics = opts.metrics.clone().unwrap_or_default();
        Ok(Self { svc, listener, opts, nloops, ctl, metrics })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.ctl.addr
    }

    /// A stop handle, clonable and usable from any thread.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { ctl: Arc::clone(&self.ctl) }
    }

    /// Connection metrics (accepted/active/dropped, wakeups, partial
    /// flushes, deferred reads).
    pub fn metrics(&self) -> &Arc<ConnMetrics> {
        &self.metrics
    }

    /// Serve until [`ServerHandle::stop`].  Consumes the server; run it
    /// on a dedicated thread.
    pub fn run(self) -> Result<()> {
        if self.opts.raise_nofile {
            #[cfg(target_os = "linux")]
            let _ = sys::raise_nofile_limit();
        }
        #[cfg(target_os = "linux")]
        if self.nloops > 0 {
            return self.run_event();
        }
        self.run_blocking()
    }

    /// Thread-per-connection fallback with stop support.
    fn run_blocking(self) -> Result<()> {
        loop {
            let sock = match self.listener.accept() {
                Ok((sock, _)) => sock,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e.into()),
            };
            // ord: SeqCst — pairs with ServerHandle::stop, whose wake
            // connection guarantees one more accept() returns.
            if self.ctl.stop.load(Ordering::SeqCst) {
                return Ok(());
            }
            // ord: Relaxed — independent telemetry counter.
            self.metrics.accepted.fetch_add(1, Ordering::Relaxed);
            // ord: Relaxed — the cap check is approximate by design; a
            // race with a closing connection widens it by at most a few.
            if self.metrics.active.load(Ordering::Relaxed) as usize >= self.opts.max_conns {
                // ord: Relaxed — telemetry counter.
                self.metrics.dropped.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            // ord: Relaxed — gauge; exactness is not load-bearing.
            self.metrics.active.fetch_add(1, Ordering::Relaxed);
            let svc = Arc::clone(&self.svc);
            let metrics = Arc::clone(&self.metrics);
            thread::spawn(move || {
                let _ = serve_conn_blocking(&*svc, sock);
                // ord: Relaxed — gauge decrement, telemetry only.
                metrics.active.fetch_sub(1, Ordering::Relaxed);
            });
        }
    }

    /// Acceptor + N event loops; returns when stopped.
    #[cfg(target_os = "linux")]
    fn run_event(&self) -> Result<()> {
        let queues: Vec<Arc<HandoffQueue<TcpStream>>> =
            (0..self.nloops).map(|_| Arc::new(HandoffQueue::new())).collect();
        thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(self.nloops);
            for (i, queue) in queues.iter().enumerate() {
                let queue = Arc::clone(queue);
                let wake = Arc::clone(&self.ctl.wakes[i]);
                let svc = Arc::clone(&self.svc);
                let ctl = &self.ctl;
                let metrics = &self.metrics;
                handles.push(
                    thread::Builder::new()
                        .name(format!("net-loop-{i}"))
                        .spawn_scoped(scope, move || {
                            event_loop(&*svc, &queue, &wake, ctl, metrics)
                        })?,
                );
            }
            let accepted = self.run_acceptor(&queues);
            // Acceptor exited (stop, or a fatal listener error): make
            // sure every loop observes stop and drains out.
            // ord: SeqCst — pairs with the loops' stop checks.
            self.ctl.stop.store(true, Ordering::SeqCst);
            for wake in &self.ctl.wakes {
                wake.signal();
            }
            for h in handles {
                h.join().expect("event loop panicked")?;
            }
            accepted
        })
    }

    /// Accept and round-robin sockets onto the loops' handoff queues.
    #[cfg(target_os = "linux")]
    fn run_acceptor(&self, queues: &[Arc<HandoffQueue<TcpStream>>]) -> Result<()> {
        let mut next = 0usize;
        loop {
            let sock = match self.listener.accept() {
                Ok((sock, _)) => sock,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e.into()),
            };
            // ord: SeqCst — pairs with ServerHandle::stop, whose wake
            // connection guarantees one more accept() returns.
            if self.ctl.stop.load(Ordering::SeqCst) {
                return Ok(());
            }
            // ord: Relaxed — telemetry counter.
            self.metrics.accepted.fetch_add(1, Ordering::Relaxed);
            // ord: Relaxed — cap check approximate by design (races with
            // loop-side decrements shift it by at most a few conns).
            if self.metrics.active.load(Ordering::Relaxed) as usize >= self.opts.max_conns {
                // ord: Relaxed — telemetry counter.
                self.metrics.dropped.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            // ord: Relaxed — gauge; the owning loop decrements on close.
            self.metrics.active.fetch_add(1, Ordering::Relaxed);
            let i = next % queues.len();
            next = next.wrapping_add(1);
            if queues[i].push(sock) {
                self.ctl.wakes[i].signal();
            }
        }
    }
}

/// Serve one already accepted connection with the blocking personality —
/// the exact `serve_framed` semantics the event path mirrors.
pub fn serve_conn_blocking<S: Service>(svc: &S, sock: TcpStream) -> Result<()> {
    sock.set_nodelay(true)?;
    let mut rd = BufReader::new(sock.try_clone()?);
    let mut wr = sock;
    let mut st = S::ConnState::default();
    proto::serve_framed(&mut rd, &mut wr, |req, out| svc.handle(&mut st, req, out))
}

/// Thread-per-connection accept loop (no stop handle; runs until the
/// listener errors).  The historical `Router::serve`/`shard::serve`
/// behavior, shared here so both binaries keep one implementation.
pub fn serve_blocking<S: Service>(svc: Arc<S>, listener: TcpListener) -> Result<()> {
    loop {
        let (sock, _) = listener.accept()?;
        let svc = Arc::clone(&svc);
        thread::spawn(move || {
            let _ = serve_conn_blocking(&*svc, sock);
        });
    }
}

// ---------------------------------------------------------------------
// Event loop internals (Linux).
// ---------------------------------------------------------------------

/// Token reserved for the loop's eventfd; connections use their slab
/// index.
#[cfg(target_os = "linux")]
const WAKE_TOKEN: u64 = u64::MAX;

/// Stop reading a socket once this much unprocessed input is buffered
/// in one pass; level-triggered epoll resumes where we left off.
#[cfg(target_os = "linux")]
const READ_BURST: usize = 1 << 20;

/// Grace period for draining in-flight connections after stop.
#[cfg(target_os = "linux")]
const DRAIN_GRACE: std::time::Duration = std::time::Duration::from_millis(1000);

/// One event-loop-owned connection.
#[cfg(target_os = "linux")]
struct EConn<S: Service> {
    sock: TcpStream,
    core: ConnCore,
    st: S::ConnState,
    peer_closed: bool,
    /// Read interest withdrawn by the backpressure rule.
    reads_deferred: bool,
    /// Interest currently registered with epoll (to elide no-op MODs).
    reg_read: bool,
    reg_write: bool,
    token: u64,
}

#[cfg(target_os = "linux")]
impl<S: Service> EConn<S> {
    fn want_read(&self) -> bool {
        !self.peer_closed && !self.core.is_broken() && !self.reads_deferred
    }

    /// Re-derive and (if changed) re-register epoll interest; applies
    /// the backpressure hysteresis and counts deferral edges.
    fn update_interest(&mut self, poller: &sys::Poller, metrics: &ConnMetrics) {
        use std::os::fd::AsRawFd;
        if !self.reads_deferred && self.core.over_high_water() {
            self.reads_deferred = true;
            // ord: Relaxed — telemetry counter.
            metrics.deferred_reads.fetch_add(1, Ordering::Relaxed);
        } else if self.reads_deferred && self.core.out_pending() <= conn::OUT_LOW_WATER {
            self.reads_deferred = false;
        }
        let want_read = self.want_read();
        let want_write = self.core.out_pending() > 0;
        if want_read != self.reg_read || want_write != self.reg_write {
            let _ = poller.modify(self.sock.as_raw_fd(), self.token, want_read, want_write);
            self.reg_read = want_read;
            self.reg_write = want_write;
        }
    }
}

/// Register a freshly handed-off socket in this loop's slab.
#[cfg(target_os = "linux")]
fn register_conn<S: Service>(
    poller: &sys::Poller,
    conns: &mut Vec<Option<EConn<S>>>,
    free: &mut Vec<usize>,
    sock: TcpStream,
) -> io::Result<()> {
    use std::os::fd::AsRawFd;
    sock.set_nonblocking(true)?;
    let _ = sock.set_nodelay(true);
    let idx = free.pop().unwrap_or_else(|| {
        conns.push(None);
        conns.len() - 1
    });
    if let Err(e) = poller.add(sock.as_raw_fd(), idx as u64, true, false) {
        free.push(idx);
        return Err(e);
    }
    conns[idx] = Some(EConn {
        sock,
        core: ConnCore::new(),
        st: S::ConnState::default(),
        peer_closed: false,
        reads_deferred: false,
        reg_read: true,
        reg_write: false,
        token: idx as u64,
    });
    Ok(())
}

/// Flush as much pending output as the socket accepts right now.
#[cfg(target_os = "linux")]
fn flush_out<S: Service>(conn: &mut EConn<S>, metrics: &ConnMetrics) -> io::Result<()> {
    use std::io::Write as _;
    while conn.core.out_pending() > 0 {
        match conn.sock.write(conn.core.output()) {
            Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
            Ok(n) => conn.core.consume_output(n),
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                // ord: Relaxed — telemetry counter.
                metrics.partial_flushes.fetch_add(1, Ordering::Relaxed);
                break;
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Advance one connection as far as readiness allows: read (unless
/// deferred), pump process↔flush to a fixed point, settle EOF, update
/// interest.  Returns `true` when the connection must be closed.
#[cfg(target_os = "linux")]
fn drive_conn<S: Service>(
    conn: &mut EConn<S>,
    svc: &S,
    poller: &sys::Poller,
    metrics: &ConnMetrics,
    rbuf: &mut [u8],
    readable: bool,
) -> bool {
    use std::io::Read as _;
    if readable && conn.want_read() {
        loop {
            match conn.sock.read(rbuf) {
                Ok(0) => {
                    conn.peer_closed = true;
                    break;
                }
                Ok(n) => {
                    conn.core.push_input(&rbuf[..n]);
                    if conn.core.in_pending() >= READ_BURST {
                        break; // fairness: level-triggered epoll re-fires
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return true, // connection reset: close now
            }
        }
    }
    // Pump process↔flush until neither side makes progress: a flush that
    // drains below the high-water mark re-enables parsing of frames that
    // were already buffered, so one pass is not enough.
    loop {
        let before = (conn.core.in_pending(), conn.core.out_pending());
        conn.core.process(svc, &mut conn.st);
        if conn.peer_closed {
            conn.core.finish_input(svc, &mut conn.st);
        }
        if flush_out(conn, metrics).is_err() {
            return true;
        }
        let after = (conn.core.in_pending(), conn.core.out_pending());
        if after == before {
            break;
        }
    }
    if conn.core.is_drained() || (conn.peer_closed && conn.core.out_pending() == 0) {
        return true;
    }
    conn.update_interest(poller, metrics);
    false
}

/// One shared-nothing event loop: owns a slab of connections, drains its
/// handoff queue on eventfd wakes, and exits once stopped and drained.
#[cfg(target_os = "linux")]
fn event_loop<S: Service>(
    svc: &S,
    queue: &HandoffQueue<TcpStream>,
    wake: &sys::WakeFd,
    ctl: &Ctl,
    metrics: &ConnMetrics,
) -> Result<()> {
    use std::time::Instant;

    let poller = sys::Poller::new()?;
    poller.add(wake.raw(), WAKE_TOKEN, true, false)?;

    let mut conns: Vec<Option<EConn<S>>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut events: Vec<sys::PollEvent> = Vec::new();
    let mut incoming: Vec<TcpStream> = Vec::new();
    let mut rbuf = vec![0u8; 64 << 10];
    let mut active = 0usize;
    let mut drain_deadline: Option<Instant> = None;

    loop {
        // ord: SeqCst — pairs with ServerHandle::stop; the eventfd wake
        // that accompanies the store bounds how long we can miss it.
        let stopping = ctl.stop.load(Ordering::SeqCst);
        if stopping {
            if active == 0 {
                return Ok(());
            }
            drain_deadline.get_or_insert_with(|| Instant::now() + DRAIN_GRACE);
        }
        let timeout_ms = if stopping { 10 } else { -1 };
        poller.wait(&mut events, timeout_ms)?;
        // ord: Relaxed — telemetry counter.
        metrics.wakeups.fetch_add(1, Ordering::Relaxed);

        let mut got_wake = false;
        for ev in &events {
            if ev.token == WAKE_TOKEN {
                got_wake = true;
                continue;
            }
            let idx = ev.token as usize;
            let close = match conns.get_mut(idx).and_then(|slot| slot.as_mut()) {
                Some(conn) => {
                    drive_conn(conn, svc, &poller, metrics, &mut rbuf, ev.readable && !stopping)
                }
                None => false,
            };
            if close {
                conns[idx] = None; // dropping the socket deregisters it
                free.push(idx);
                active -= 1;
                // ord: Relaxed — gauge decrement, telemetry only.
                metrics.active.fetch_sub(1, Ordering::Relaxed);
            }
        }

        if got_wake {
            wake.drain_counter();
            queue.drain(&mut incoming);
        }
        for sock in incoming.drain(..) {
            if stopping {
                // Accepted but never served: account it back out.
                // ord: Relaxed — gauge/telemetry adjustments.
                metrics.active.fetch_sub(1, Ordering::Relaxed);
                metrics.dropped.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            match register_conn(&poller, &mut conns, &mut free, sock) {
                Ok(()) => active += 1,
                Err(_) => {
                    // ord: Relaxed — gauge/telemetry adjustments.
                    metrics.active.fetch_sub(1, Ordering::Relaxed);
                    metrics.dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
        }

        if stopping {
            // Drain tick: answer whatever is already buffered, flush,
            // and close each connection as its output empties — or
            // unconditionally once the grace period lapses.
            let force = drain_deadline.is_some_and(|d| Instant::now() >= d);
            for (idx, slot) in conns.iter_mut().enumerate() {
                let close = match slot.as_mut() {
                    None => continue,
                    Some(conn) if force => {
                        let _ = flush_out(conn, metrics);
                        true
                    }
                    Some(conn) => {
                        conn.core.finish_input(svc, &mut conn.st);
                        let _ = flush_out(conn, metrics);
                        conn.core.out_pending() == 0
                    }
                };
                if close {
                    *slot = None;
                    free.push(idx);
                    active -= 1;
                    // ord: Relaxed — gauge decrement, telemetry only.
                    metrics.active.fetch_sub(1, Ordering::Relaxed);
                }
            }
        }
    }
}
