//! Raw Linux readiness plumbing: `epoll`, `eventfd`, and `RLIMIT_NOFILE`.
//!
//! The crate's dependency discipline (std + `anyhow` only — see
//! `Cargo.toml`) rules out the `libc` crate as much as tokio/mio, so the
//! handful of syscalls the event server needs are declared here directly:
//! std already links the platform C library, and on Linux these symbols
//! and their ABI are stable.  Everything in this module is
//! `#[cfg(target_os = "linux")]` (gated at the `mod` declaration in
//! `net`); other platforms fall back to the blocking server.
//!
//! Wrappers own their fds ([`OwnedFd`]/[`File`]) so a dropped [`Poller`]
//! or [`WakeFd`] closes cleanly, and every raw call checks the return
//! value and converts `-1` into [`io::Error::last_os_error`].

use std::fs::File;
use std::io::{self, Read as _, Write as _};
use std::os::fd::{AsRawFd, FromRawFd, OwnedFd, RawFd};

// ---------------------------------------------------------------------
// ABI constants (uapi values; stable on Linux).
// ---------------------------------------------------------------------

const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;

const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;

const EPOLL_CLOEXEC: i32 = 0o2000000;
const EFD_CLOEXEC: i32 = 0o2000000;
const EFD_NONBLOCK: i32 = 0o4000;

const RLIMIT_NOFILE: i32 = 7;

/// Kernel `struct epoll_event`: packed on x86-64 (the one architecture
/// where the uapi header says so), naturally aligned elsewhere.
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

#[repr(C)]
struct Rlimit {
    cur: u64,
    max: u64,
}

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
}

/// One readiness report from [`Poller::wait`], with the epoll flag salad
/// already folded down to the two questions the event loop asks.
/// `ERR`/`HUP` set both: the loop's next `read`/`write` surfaces the
/// actual error, which is the one place connection teardown lives.
#[derive(Debug, Clone, Copy)]
pub struct PollEvent {
    /// The `u64` token registered with the fd (slab index or wake token).
    pub token: u64,
    /// Readable (or error/hangup — reading reveals which).
    pub readable: bool,
    /// Writable (or error/hangup).
    pub writable: bool,
}

/// Level-triggered epoll instance.
#[derive(Debug)]
pub struct Poller {
    ep: OwnedFd,
}

impl Poller {
    /// New epoll instance (`CLOEXEC`).
    pub fn new() -> io::Result<Self> {
        // SAFETY: epoll_create1 takes a plain flag word and returns a new
        // fd or -1; no pointers are involved.
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        // SAFETY: `fd` is a freshly created epoll fd that nothing else
        // owns; OwnedFd takes over closing it.
        Ok(Self { ep: unsafe { OwnedFd::from_raw_fd(fd) } })
    }

    fn ctl(&self, op: i32, fd: RawFd, interest: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events: interest, data: token };
        // SAFETY: `ev` is a live, properly laid out epoll_event for the
        // duration of the call; the kernel only reads it.
        let rc = unsafe { epoll_ctl(self.ep.as_raw_fd(), op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    fn interest_bits(read: bool, write: bool) -> u32 {
        let mut bits = 0;
        if read {
            bits |= EPOLLIN;
        }
        if write {
            bits |= EPOLLOUT;
        }
        bits
    }

    /// Register `fd` with the given interest, tagged with `token`.
    pub fn add(&self, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, Self::interest_bits(read, write), token)
    }

    /// Change the interest set of an already registered `fd`.
    pub fn modify(&self, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, Self::interest_bits(read, write), token)
    }

    /// Deregister `fd`.  (Closing the fd deregisters implicitly; this is
    /// for fds that outlive their registration.)
    pub fn delete(&self, fd: RawFd) -> io::Result<()> {
        // Pre-2.6.9 kernels dereference the event pointer even for DEL,
        // so pass a real (ignored) struct rather than null.
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Wait for readiness; `timeout_ms < 0` blocks indefinitely.  Fills
    /// `events` (cleared first) and retries transparently on `EINTR`.
    pub fn wait(&self, events: &mut Vec<PollEvent>, timeout_ms: i32) -> io::Result<()> {
        const CAP: usize = 512;
        let mut raw = [EpollEvent { events: 0, data: 0 }; CAP];
        loop {
            // SAFETY: `raw` is a live array of CAP properly laid out
            // epoll_events and maxevents matches its length, so the
            // kernel writes only within bounds.
            let n = unsafe { epoll_wait(self.ep.as_raw_fd(), raw.as_mut_ptr(), CAP as i32, timeout_ms) };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(err);
            }
            events.clear();
            for ev in raw.iter().take(n as usize) {
                // Copy out of the (possibly packed) struct by value.
                let bits = ev.events;
                let token = ev.data;
                events.push(PollEvent {
                    token,
                    readable: bits & (EPOLLIN | EPOLLERR | EPOLLHUP) != 0,
                    writable: bits & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                });
            }
            return Ok(());
        }
    }
}

/// Nonblocking `eventfd` used to kick an event loop out of `epoll_wait`
/// (new handoff sockets, stop signal).
#[derive(Debug)]
pub struct WakeFd {
    file: File,
}

impl WakeFd {
    /// New nonblocking, CLOEXEC eventfd with a zero counter.
    pub fn new() -> io::Result<Self> {
        // SAFETY: eventfd takes plain integer arguments and returns a new
        // fd or -1; no pointers are involved.
        let fd = unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        // SAFETY: `fd` is a freshly created eventfd that nothing else
        // owns; File takes over closing it.
        Ok(Self { file: unsafe { File::from_raw_fd(fd) } })
    }

    /// The fd to register for read interest in a [`Poller`].
    pub fn raw(&self) -> RawFd {
        self.file.as_raw_fd()
    }

    /// Wake the poller.  Infallible by design: the only write failure on
    /// a nonblocking eventfd is a saturated counter, and a saturated
    /// counter is already a pending wake.
    pub fn signal(&self) {
        let one = 1u64.to_ne_bytes();
        let _ = (&self.file).write(&one);
    }

    /// Consume the pending wake(s).  A single read returns-and-resets the
    /// whole counter, so coalesced signals cost one syscall.
    pub fn drain_counter(&self) {
        let mut buf = [0u8; 8];
        let _ = (&self.file).read(&mut buf);
    }
}

/// Raise the soft `RLIMIT_NOFILE` to the hard limit and return the new
/// soft limit.  10k+ connections exceed the common 1024 default; callers
/// treat failure as advisory (the accept path degrades by dropping).
pub fn raise_nofile_limit() -> io::Result<u64> {
    let mut lim = Rlimit { cur: 0, max: 0 };
    // SAFETY: `lim` is a live, properly laid out rlimit the kernel fills.
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } < 0 {
        return Err(io::Error::last_os_error());
    }
    if lim.cur < lim.max {
        lim.cur = lim.max;
        // SAFETY: `lim` is live and only read by the kernel.
        if unsafe { setrlimit(RLIMIT_NOFILE, &lim) } < 0 {
            return Err(io::Error::last_os_error());
        }
    }
    Ok(lim.cur)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn poller_reports_readable_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let poller = Poller::new().unwrap();
        poller.add(server.as_raw_fd(), 7, true, false).unwrap();

        let mut events = Vec::new();
        poller.wait(&mut events, 0).unwrap();
        assert!(events.is_empty(), "no data yet: poll must time out empty");

        client.write_all(b"x").unwrap();
        client.flush().unwrap();
        poller.wait(&mut events, 1000).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);

        // Interest change to write-only: a connected socket with room in
        // its send buffer is immediately writable.
        poller.modify(server.as_raw_fd(), 7, false, true).unwrap();
        poller.wait(&mut events, 1000).unwrap();
        assert_eq!(events.len(), 1);
        assert!(events[0].writable);

        poller.delete(server.as_raw_fd()).unwrap();
        poller.wait(&mut events, 0).unwrap();
        assert!(events.is_empty(), "deregistered fd must not report");
    }

    #[test]
    fn wakefd_signals_and_coalesces() {
        let poller = Poller::new().unwrap();
        let wake = WakeFd::new().unwrap();
        poller.add(wake.raw(), u64::MAX, true, false).unwrap();

        let mut events = Vec::new();
        poller.wait(&mut events, 0).unwrap();
        assert!(events.is_empty());

        wake.signal();
        wake.signal(); // coalesces into the same counter
        poller.wait(&mut events, 1000).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, u64::MAX);

        wake.drain_counter();
        poller.wait(&mut events, 0).unwrap();
        assert!(events.is_empty(), "drained eventfd must be quiet");
    }

    #[test]
    fn nofile_limit_reports_a_sane_value() {
        let lim = raise_nofile_limit().unwrap();
        assert!(lim >= 256, "soft nofile limit suspiciously low: {lim}");
    }
}
