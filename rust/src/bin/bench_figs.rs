//! `bench_figs` — regenerates every figure in the paper's §6 evaluation
//! plus the §5.4 theory validations (closed forms in `stats::theory`;
//! see PAPER.md for the source abstract).
//!
//! ```text
//! bench_figs fig5        lookup time vs cluster size          (Fig. 5)
//! bench_figs fig6        least/most loaded relative diff      (Fig. 6)
//! bench_figs fig7        relative stddev, mean=1000           (Fig. 7)
//! bench_figs fig8        stddev while scaling to 64 nodes     (Fig. 8)
//! bench_figs eq3         measured vs closed-form imbalance    (Eq. 3)
//! bench_figs eq6         sigma_max bound validation           (Eq. 6)
//! bench_figs disruption  monotonicity / minimal disruption sweep
//! bench_figs all         everything above
//! ```
//!
//! Flags: `--quick <bool>` shrinks workloads ~10×; `--out <dir>` writes
//! CSV series (default `results/`).  All workloads are seeded and
//! deterministic.

use std::collections::HashMap;
use std::fmt::Write as _;
use std::hint::black_box;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use binhash::algorithms::{self, ConsistentHasher, ALL_ALGORITHMS, PAPER_ALGORITHMS};
use binhash::stats::{theory, BalanceStats};
use binhash::workload::UniformDigests;

struct Ctx {
    quick: bool,
    out_dir: String,
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().cloned() else {
        bail!("usage: bench_figs <fig5|fig6|fig7|fig8|eq3|eq6|disruption|all> \
               [--quick true] [--out results]");
    };
    let mut ctx = Ctx { quick: false, out_dir: "results".into() };
    let mut it = args[1..].iter();
    while let Some(flag) = it.next() {
        let v = it.next().ok_or_else(|| anyhow!("{flag} missing value"))?;
        match flag.as_str() {
            "--quick" => ctx.quick = v.parse()?,
            "--out" => ctx.out_dir = v.clone(),
            other => bail!("unknown flag {other}"),
        }
    }
    std::fs::create_dir_all(&ctx.out_dir)?;
    match cmd.as_str() {
        "fig5" => fig5(&ctx)?,
        "fig6" => fig6(&ctx)?,
        "fig7" => fig7(&ctx)?,
        "fig8" => fig8(&ctx)?,
        "eq3" => eq3(&ctx)?,
        "eq6" => eq6(&ctx)?,
        "disruption" => disruption(&ctx)?,
        "all" => {
            fig5(&ctx)?;
            fig6(&ctx)?;
            fig7(&ctx)?;
            fig8(&ctx)?;
            eq3(&ctx)?;
            eq6(&ctx)?;
            disruption(&ctx)?;
        }
        other => bail!("unknown experiment {other}"),
    }
    Ok(())
}

fn save_csv(ctx: &Ctx, name: &str, content: &str) -> Result<()> {
    let path = format!("{}/{name}.csv", ctx.out_dir);
    std::fs::write(&path, content)?;
    eprintln!("  wrote {path}");
    Ok(())
}

/// ns/op for one algorithm instance over pre-generated digests.
fn time_lookup(engine: &dyn ConsistentHasher, digests: &[u64]) -> f64 {
    // Warm-up pass.
    let mut acc = 0u64;
    for &d in &digests[..digests.len() / 10] {
        acc = acc.wrapping_add(engine.bucket(d) as u64);
    }
    let start = Instant::now();
    for &d in digests {
        acc = acc.wrapping_add(engine.bucket(d) as u64);
    }
    let elapsed = start.elapsed();
    black_box(acc);
    elapsed.as_nanos() as f64 / digests.len() as f64
}

// ---------------------------------------------------------------- Fig. 5

fn fig5(ctx: &Ctx) -> Result<()> {
    println!("\n== Fig. 5: lookup time (ns/op) vs cluster size ==");
    let sizes: &[u32] = &[10, 100, 1_000, 10_000, 100_000];
    let k = if ctx.quick { 200_000 } else { 2_000_000 };
    let digests = UniformDigests::new(0xF1_65).take_vec(k);

    // Paper's four constant-time algorithms first, then the wider suite.
    let mut order: Vec<&str> = PAPER_ALGORITHMS.to_vec();
    for a in ALL_ALGORITHMS {
        if !order.contains(a) {
            order.push(a);
        }
    }

    let mut csv = String::from("algorithm,n,ns_per_lookup\n");
    print!("{:<12}", "algorithm");
    for n in sizes {
        print!("{:>12}", format!("n={n}"));
    }
    println!();
    for name in &order {
        print!("{name:<12}");
        for &n in sizes {
            // Ring/maglev/multiprobe are memory-heavy; skip their largest
            // sizes in quick mode to keep runtime sane.
            let heavy = matches!(*name, "ring" | "maglev" | "multiprobe" | "rendezvous");
            if heavy && n > 10_000 {
                print!("{:>12}", "-");
                continue;
            }
            let engine = algorithms::by_name(name, n).unwrap();
            let slice = if heavy { &digests[..k / 10] } else { &digests[..] };
            let ns = time_lookup(engine.as_ref(), slice);
            print!("{ns:>12.1}");
            writeln!(csv, "{name},{n},{ns:.2}").unwrap();
        }
        println!();
    }
    save_csv(ctx, "fig5_lookup_time", &csv)
}

// ----------------------------------------------------------- Fig. 6/7/8

fn histogram_for(name: &str, n: u32, k: usize, seed: u64) -> Vec<u64> {
    let engine = algorithms::by_name(name, n).unwrap();
    let mut counts = vec![0u64; n as usize];
    for d in UniformDigests::new(seed).take(k) {
        counts[engine.bucket(d) as usize] += 1;
    }
    counts
}

fn fig6(ctx: &Ctx) -> Result<()> {
    println!("\n== Fig. 6: least/most loaded node relative difference (mean=1000) ==");
    let sizes: &[u32] = if ctx.quick { &[10, 100, 1_000] } else { &[10, 100, 1_000, 10_000] };
    let mut csv = String::from("algorithm,n,min_rel,max_rel\n");
    println!("{:<12}{:>8}{:>12}{:>12}", "algorithm", "n", "least%", "most%");
    for name in PAPER_ALGORITHMS {
        for &n in sizes {
            let k = 1_000usize * n as usize;
            let counts = histogram_for(name, n, k, 0xF1_66);
            let s = BalanceStats::from_counts(&counts);
            let (min_rel, max_rel) = s.min_max_relative();
            println!("{name:<12}{n:>8}{:>11.2}%{:>11.2}%", min_rel * 100.0, max_rel * 100.0);
            writeln!(csv, "{name},{n},{min_rel:.5},{max_rel:.5}").unwrap();
        }
    }
    save_csv(ctx, "fig6_min_max_relative", &csv)
}

fn fig7(ctx: &Ctx) -> Result<()> {
    println!("\n== Fig. 7: relative standard deviation (mean=1000) ==");
    let sizes: &[u32] =
        if ctx.quick { &[10, 100, 1_000] } else { &[10, 50, 100, 500, 1_000, 5_000, 10_000] };
    let mut csv = String::from("algorithm,n,rel_stddev\n");
    print!("{:<12}", "algorithm");
    for n in sizes {
        print!("{:>10}", format!("n={n}"));
    }
    println!();
    for name in PAPER_ALGORITHMS {
        print!("{name:<12}");
        for &n in sizes {
            let k = 1_000usize * n as usize;
            let counts = histogram_for(name, n, k, 0xF1_67);
            let rel = BalanceStats::from_counts(&counts).rel_stddev();
            print!("{:>9.2}%", rel * 100.0);
            writeln!(csv, "{name},{n},{rel:.5}").unwrap();
        }
        println!();
    }
    save_csv(ctx, "fig7_rel_stddev", &csv)
}

fn fig8(ctx: &Ctx) -> Result<()> {
    println!("\n== Fig. 8: stddev of keys per node, scaling 2..64 nodes (mean=1000) ==");
    let q = 1_000usize;
    let step = if ctx.quick { 8 } else { 1 };
    let mut csv = String::from("algorithm,n,stddev,theory_eq5\n");
    println!("{:<12}{:>6}{:>12}{:>14}", "algorithm", "n", "stddev", "eq5(binomial)");
    for name in PAPER_ALGORITHMS {
        for n in (2u32..=64).step_by(step) {
            let k = q * n as usize;
            let counts = histogram_for(name, n, k, 0xF1_68);
            let s = BalanceStats::from_counts(&counts);
            let th = if *name == "binomial" {
                theory::stddev(n, binhash::algorithms::binomial::DEFAULT_OMEGA, k as u64)
            } else {
                f64::NAN
            };
            if n % 8 == 0 || ctx.quick {
                println!("{name:<12}{n:>6}{:>12.1}{:>14.1}", s.stddev, th);
            }
            writeln!(csv, "{name},{n},{:.3},{th:.3}", s.stddev).unwrap();
        }
    }
    save_csv(ctx, "fig8_stddev_scaling", &csv)
}

// ------------------------------------------------------------ Eq. 3 / 6

fn eq3(ctx: &Ctx) -> Result<()> {
    println!("\n== Eq. 3: relative imbalance, measured vs closed form (M=32) ==");
    let m = 32u32;
    let k = if ctx.quick { 400_000 } else { 4_000_000 };
    let mut csv = String::from("omega,n,measured,closed_form,bound\n");
    println!("{:>6}{:>6}{:>12}{:>12}{:>12}", "omega", "n", "measured", "eq3", "2^-w");
    for omega in [1u32, 2, 4, 6, 8] {
        for n in [m + 1, m + 8, m + 16, m + 24, 2 * m - 1] {
            let mut counts = vec![0u64; n as usize];
            for d in UniformDigests::new(0xE9_3 + omega as u64).take(k) {
                counts[binhash::algorithms::binomial::lookup(d, n, omega) as usize] += 1;
            }
            let k_minor: f64 =
                counts[..m as usize].iter().sum::<u64>() as f64 / m as f64;
            let k_level: f64 =
                counts[m as usize..].iter().sum::<u64>() as f64 / (n - m) as f64;
            let measured = (k_minor - k_level) / (k as f64 / n as f64);
            let closed = theory::relative_imbalance(n, omega);
            let bound = theory::relative_imbalance_bound(omega);
            println!("{omega:>6}{n:>6}{measured:>12.5}{closed:>12.5}{bound:>12.5}");
            writeln!(csv, "{omega},{n},{measured:.6},{closed:.6},{bound:.6}").unwrap();
        }
    }
    save_csv(ctx, "eq3_imbalance", &csv)
}

fn eq6(ctx: &Ctx) -> Result<()> {
    println!("\n== Eq. 6: sigma bound (omega=5, q=1000): sigma_max ≈ 0.045q ==");
    let omega = 5u32;
    let q = 1_000u64;
    let m = 32u32;
    let mut csv =
        String::from("n,measured_sigma,predicted_total,structural,eq5_printed,eq6_bound\n");
    let bound = theory::stddev_max(omega, q as f64);
    println!("  eq6 bound = {bound:.2} ({:.4}·q)", bound / q as f64);
    println!(
        "{:>6}{:>14}{:>12}{:>12}{:>12}{:>12}",
        "n", "measured σ", "predicted", "structural", "eq5-print", "eq6 bound"
    );
    for n in [m + 1, m + 8, theory::stddev_argmax(omega, m), 2 * m - 8, 2 * m - 1] {
        let k = (q * n as u64) as usize * if ctx.quick { 1 } else { 10 };
        let mut counts = vec![0u64; n as usize];
        for d in UniformDigests::new(0xE9_6).take(k) {
            counts[binhash::algorithms::binomial::lookup(d, n, omega) as usize] += 1;
        }
        // Scale measured sigma back to q keys/bucket for comparability.
        let s = BalanceStats::from_counts(&counts);
        let scale = q as f64 / s.mean;
        let sigma = s.stddev * scale;
        // Predicted = structural (re-derived Eq. 5; see stats::theory) +
        // multinomial sampling noise at the *actual* per-bucket load,
        // rescaled to q.
        let q_actual = s.mean;
        let structural = theory::stddev_structural(n, omega, q * n as u64);
        let predicted = {
            let st = theory::stddev_structural(n, omega, (q_actual * n as f64) as u64);
            ((st * st + q_actual * (1.0 - 1.0 / n as f64)).sqrt()) * scale
        };
        let printed = theory::stddev(n, omega, q * n as u64);
        println!(
            "{n:>6}{sigma:>14.2}{predicted:>12.2}{structural:>12.2}{printed:>12.2}{bound:>12.2}"
        );
        writeln!(csv, "{n},{sigma:.3},{predicted:.3},{structural:.3},{printed:.3},{bound:.3}")
            .unwrap();
    }
    println!(
        "  note: the paper's printed Eq. 5 places ^ω inside the sqrt; deriving from\n\
         Eqs. 1/2/4 puts it outside (stats::theory::stddev_structural). Measurements\n\
         track structural+sampling and stay under the Eq. 6 bound, as the paper claims."
    );
    save_csv(ctx, "eq6_sigma_bound", &csv)
}

// -------------------------------------------------------- disruption

fn disruption(ctx: &Ctx) -> Result<()> {
    println!("\n== Monotonicity / minimal disruption sweep (n -> n+1 -> n) ==");
    let k = if ctx.quick { 100_000 } else { 1_000_000 };
    let digests = UniformDigests::new(0xD15).take_vec(k);
    let mut csv = String::from("algorithm,n,moved_frac,expected_frac,violations\n");
    println!(
        "{:<12}{:>8}{:>12}{:>12}{:>12}",
        "algorithm", "n", "moved", "expect", "violations"
    );
    let mut names: Vec<&str> = ALL_ALGORITHMS.to_vec();
    names.push(algorithms::ANTI_BASELINE); // what non-consistency costs
    for name in &names {
        // maglev is only approximately minimal — report it, don't assert.
        for &n in &[8u32, 31, 100] {
            let a = algorithms::by_name(name, n).unwrap();
            let b = algorithms::by_name(name, n + 1).unwrap();
            let mut moved = 0usize;
            // A key that changes bucket without landing on the new bucket
            // violates BOTH monotonicity (n→n+1) and minimal disruption
            // (n+1→n, mirror image).
            let mut violations = 0usize;
            for &d in &digests {
                let x = a.bucket(d);
                let y = b.bucket(d);
                if x != y {
                    moved += 1;
                    if y != n {
                        violations += 1;
                    }
                }
            }
            let frac = moved as f64 / k as f64;
            let expect = 1.0 / (n + 1) as f64;
            println!(
                "{name:<12}{n:>8}{:>11.3}%{:>11.3}%{violations:>12}",
                frac * 100.0,
                expect * 100.0
            );
            writeln!(csv, "{name},{n},{frac:.6},{expect:.6},{violations}").unwrap();
        }
    }
    save_csv(ctx, "disruption", &csv)
}

// Silence dead-code lint for maps only used in some subcommands.
#[allow(dead_code)]
fn unused(_: HashMap<String, String>) {}
