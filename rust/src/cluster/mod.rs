//! Cluster membership: the epoch-stamped mapping from buckets to shards.
//!
//! Two shapes live here:
//!
//! * [`Cluster`] — the *mutable* construction-time description (placement
//!   engine + shard handles + event log). Shards join and leave in LIFO
//!   order (the paper's §1 operating model); arbitrary failures are
//!   handled by the Memento-wrapped engine (see
//!   `rust/examples/failover_memento.rs`).
//! * [`PlacementSnapshot`] — the *immutable*, epoch-stamped view the
//!   router's data path routes with. The router consumes a `Cluster` into
//!   its first snapshot and publishes a fresh `Arc<PlacementSnapshot>`
//!   (through [`SnapshotCell`](crate::sync::cell::SnapshotCell)) on
//!   every topology change — each epoch's engine is a
//!   [`fork`](crate::algorithms::ConsistentHasher::fork) of the previous
//!   epoch's, never a by-name rebuild — so GET/PUT/DEL never contend with
//!   a migration and stateful engines keep their full placement state.
//!   While keys are still in flight the snapshot carries a
//!   [`MigrationOrigin`] — a fork of the previous epoch's engine —
//!   enabling dual-read (new owner, then old owner) routing.
//!
//! A snapshot can additionally be **degraded** ([`DegradedState`]): one
//! or more shards have *failed* (arbitrary removal, not LIFO retirement).
//! The engine — a fault-tolerant one, reached through
//! [`as_fault_tolerant_mut`](crate::algorithms::ConsistentHasher::as_fault_tolerant_mut)
//! on a fork — already routes every key to a survivor; the degraded state
//! records *which* bucket ids are dead (their shard handles stay in
//! `shards` so indices never shift, but must never be contacted) and the
//! pre-failure placement, so a miss on a key whose data is marooned on a
//! dead shard answers a distinguishable `UNAVAILABLE` error instead of
//! `NIL` — or worse, a hang on a dead connection.
//!
//! # The placement stack
//!
//! Placement is no longer one hard-wired `engine.bucket(digest)` call
//! but a stack of composable layers, each consuming the
//! [`ConsistentHasher`] surface of the one below and presenting the
//! same surface above:
//!
//! ```text
//!   engine            one of the 13 registered algorithms
//!     └─ Weighted     optional: W virtual buckets → N shards via a
//!        (algorithms::weighted)   per-shard weight table; weight
//!                                 changes are vbucket add/remove =
//!                                 incremental migration for free
//!        └─ ReplicaMap   optional (factor > 1): derived top-R
//!                        secondary placements
//!           └─ PlacementSnapshot  the frozen, epoch-stamped view the
//!                                 router's data path routes with
//! ```
//!
//! Every layer forwards `fork`/`minimal_disruption`/`max_buckets`/
//! `as_fault_tolerant`, so scaling, failover, and replication compose
//! unchanged whichever layers are present: the router only ever sees a
//! `Box<dyn ConsistentHasher>`, and [`ReplicaMap::build`] runs the same
//! minus-fork (or re-hash probe) construction against a weighted engine
//! as against a bare one.  The router-side hot-key cache sits *above*
//! this stack, in front of shard I/O — its invalidation rule (write-
//! invalidated, cleared on every epoch publish so it never serves
//! across a topology change) is documented in `router::cache`.
//!
//! With `replication.factor` R > 1 a snapshot also carries a
//! [`ReplicaMap`]: the derived *secondary* placements that put every key
//! on its top-R buckets.  For fault-tolerant engines the rank-1 replica
//! of a key with primary `p` is `(engine − p).bucket(digest)` — the same
//! fork + `remove_arbitrary` construction the failover path uses to
//! build a degraded engine, which is exactly what makes a failed
//! primary's keys land *on* their replica after `FAIL`.  The per-bucket
//! "minus" forks are precomputed once per publication (topology changes
//! are rare), so the hot path derives a replica with one engine lookup
//! and zero allocation.  Rank-1-only engines without a fault-tolerant
//! surface (binomial, jump, …) fall back to a deterministic re-hash
//! probe with exclusion; both schemes are pure functions of
//! `(engine, digest, rank)`, so writer, reader, and anti-entropy sweep
//! always agree on the replica set.

use std::time::SystemTime;

use crate::algorithms::ConsistentHasher;
use crate::hashing::splitmix64;
use crate::shard::ShardClient;

/// Seed folded into the digest for the re-hash replica probe of engines
/// without a fault-tolerant surface.  Any fixed odd-ish constant works;
/// it only has to differ per probe attempt and stay stable forever
/// (replica placement is part of the data layout).
const REPLICA_PROBE_SEED: u64 = 0x9E37_79B9_5EED_0008;

/// A topology change.
#[derive(Debug, Clone)]
pub struct TopologyEvent {
    /// Epoch after the change.
    pub epoch: u64,
    /// What happened.
    pub kind: EventKind,
    /// Wall-clock timestamp.
    pub at: SystemTime,
}

/// Event kind.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EventKind {
    /// Bucket joined (always id = n−1 at that epoch).
    Joined(u32),
    /// Bucket left (always the last-added).
    Left(u32),
    /// Bucket failed (arbitrary removal; data marooned until restore).
    Failed(u32),
    /// Bucket restored after a failure (rejoins empty; keys written to
    /// survivors while it was down migrate back to it).
    Restored(u32),
    /// Shard's weight changed on a weighted placement stack (virtual
    /// buckets added or shed; the affected key share migrated
    /// incrementally like any scale op).
    Reweighted(u32),
}

/// The previous topology's placement, kept inside a migrating
/// [`PlacementSnapshot`] so the data path can fall back to a key's old
/// owner until the migration sweep has copied it.
pub struct MigrationOrigin {
    /// Placement engine of the epoch being migrated away from (an
    /// unmodified fork of that epoch's engine).
    pub engine: Box<dyn ConsistentHasher>,
    /// Bucket ids the migration scans for movable keys: every *reachable*
    /// old shard on scale-up and on a failed-shard restore, but only the
    /// retiring shard on scale-down when the engine guarantees minimal
    /// disruption (engines without it — maglev, modulo — scan everything
    /// there too).  A list, not a range, because a degraded topology has
    /// holes: a dead shard must never be scanned.
    pub sources: Vec<u32>,
    /// Shard-list length once this migration settles: one less than the
    /// migrating snapshot's list on scale-down (the retiring handle is
    /// dropped), unchanged otherwise.  Recorded explicitly so an
    /// interrupted migration can be resumed and settled without
    /// inferring the intent from engine/list length arithmetic — which
    /// breaks down on degraded topologies, where the engine's working
    /// count is always below the slot count.
    pub settle_len: usize,
    /// `Some(bucket)` when this migration is an anti-entropy restore
    /// *into* that bucket: the sweep fetches the destination's
    /// per-stripe digests once up front and skips every `(source,
    /// stripe)` scan whose digest already matches, so a restore streams
    /// only divergent stripes instead of every survivor's full
    /// keyspace.  `None` on scale-up/scale-down migrations, which fan
    /// out to many destinations and always scan.
    pub ae_dest: Option<u32>,
}

/// Derived secondary placements for `replication.factor` R > 1: maps a
/// key's `(digest, primary)` to its replica buckets.  Immutable once
/// built (snapshots never mutate after publication), so the data path
/// reads it lock-free exactly like the engine itself.
pub struct ReplicaMap {
    /// Configured replication factor (≥ 2 when a map exists at all; a
    /// factor-1 snapshot carries `None` and pays nothing).
    factor: u32,
    /// For fault-tolerant engines: `minus[b]` is a fork of the snapshot
    /// engine with working bucket `b` removed, so the rank-1 replica of
    /// a key whose primary is `b` is one O(1) lookup.  `None` entries
    /// are non-working (failed) buckets.  Empty for engines without a
    /// fault-tolerant surface, which use the re-hash probe instead.
    minus: Vec<Option<Box<dyn ConsistentHasher>>>,
}

impl ReplicaMap {
    /// Build the replica map for one published snapshot, or `None` when
    /// replication is off (`factor <= 1`) or impossible (fewer than two
    /// working buckets).  `slots` is the snapshot's shard-list length —
    /// on a degraded topology it exceeds the engine's working count.
    pub fn build(
        engine: &dyn ConsistentHasher,
        slots: usize,
        factor: u32,
    ) -> Option<Self> {
        if factor <= 1 || engine.len() < 2 {
            return None;
        }
        let minus = if engine.as_fault_tolerant().is_some() {
            (0..slots as u32)
                .map(|b| {
                    let working = match engine.as_fault_tolerant() {
                        Some(ft) => ft.is_working(b),
                        None => true,
                    };
                    if !working {
                        return None;
                    }
                    let mut fork = engine.fork();
                    let ft = fork.as_fault_tolerant_mut()?;
                    ft.remove_arbitrary(b);
                    Some(fork)
                })
                .collect()
        } else {
            Vec::new()
        };
        Some(Self { factor, minus })
    }

    /// Configured replication factor.
    pub fn factor(&self) -> u32 {
        self.factor
    }

    /// The precomputed rank-1 minus fork for `primary`, when the engine
    /// is fault-tolerant and the bucket is working.  The batched replica
    /// fan-out uses it to place a whole primary-bucket group through the
    /// fork's `bucket_batch` in one call; `None` (probe engines, failed
    /// buckets) falls back to per-key [`replicas_into`](Self::replicas_into).
    #[inline]
    pub fn rank1_fork(&self, primary: u32) -> Option<&dyn ConsistentHasher> {
        self.minus.get(primary as usize)?.as_deref()
    }

    /// The rank-1 replica of a key: one engine lookup, no allocation.
    /// `None` when the primary has no live replica (e.g. the minus fork
    /// could not be built).
    #[inline]
    pub fn first_replica(
        &self,
        engine: &dyn ConsistentHasher,
        digest: u64,
        primary: u32,
    ) -> Option<u32> {
        if !self.minus.is_empty() {
            let m = self.minus.get(primary as usize)?.as_ref()?;
            return Some(m.bucket(digest));
        }
        self.probe_replica(engine, digest, primary, &[])
    }

    /// Append the key's replica buckets (up to `factor − 1`, primary
    /// excluded, in rank order) to `out`.  Rank 1 reads the precomputed
    /// minus fork; deeper ranks fork on demand — acceptable because
    /// they only run on R > 2 configurations or slow fallback paths.
    pub fn replicas_into(
        &self,
        engine: &dyn ConsistentHasher,
        digest: u64,
        primary: u32,
        out: &mut Vec<u32>,
    ) {
        let base = out.len();
        let want = (self.factor.saturating_sub(1)) as usize;
        if want == 0 {
            return;
        }
        if !self.minus.is_empty() {
            let Some(m1) = self.minus.get(primary as usize).and_then(|o| o.as_ref())
            else {
                return;
            };
            out.push(m1.bucket(digest));
            if want >= 2 {
                let mut cur = m1.fork();
                while out.len() - base < want && cur.len() > 1 {
                    let last = *out.last().expect("pushed above");
                    match cur.as_fault_tolerant_mut() {
                        Some(ft) => ft.remove_arbitrary(last),
                        None => break,
                    }
                    out.push(cur.bucket(digest));
                }
            }
            return;
        }
        // Re-hash probe for rank-1-only engines (never degraded: only
        // fault-tolerant engines can hold failures).
        let n = engine.len() as usize;
        let want = want.min(n.saturating_sub(1));
        while out.len() - base < want {
            match self.probe_replica(engine, digest, primary, &out[base..]) {
                Some(b) => out.push(b),
                None => break,
            }
        }
    }

    /// One probe round: the lowest-rank replica not yet in `chosen`.
    /// Bounded re-hash attempts, then a deterministic linear fallback so
    /// the answer is total whenever a distinct bucket exists.
    fn probe_replica(
        &self,
        engine: &dyn ConsistentHasher,
        digest: u64,
        primary: u32,
        chosen: &[u32],
    ) -> Option<u32> {
        let n = engine.len();
        if n < 2 {
            return None;
        }
        let attempts = 8 * (chosen.len() as u64 + 2);
        for j in 0..attempts {
            let salted = splitmix64(digest ^ REPLICA_PROBE_SEED.wrapping_add(j));
            let cand = engine.bucket(salted);
            if cand != primary && !chosen.contains(&cand) {
                return Some(cand);
            }
        }
        for k in 1..=n {
            let cand = (primary + k) % n;
            if cand != primary && !chosen.contains(&cand) {
                return Some(cand);
            }
        }
        None
    }
}

/// An immutable, epoch-stamped placement view: frozen engine + shard
/// handles + optional in-flight migration origin.
///
/// Published by the router through an atomic pointer swap (a hand-rolled
/// std-only arc-swap; see `router` for the reader-gate protocol); never
/// mutated after publication, so the data path reads it lock-free — one
/// atomic load plus a refcount bump, no `RwLock` anywhere.
/// During a migration the shard list covers the *union* of the old and
/// new topologies (scale-down keeps the retiring shard reachable for
/// dual reads until the final snapshot drops it).
pub struct PlacementSnapshot {
    /// Epoch this snapshot was published at (monotonically non-decreasing
    /// across publications).
    pub epoch: u64,
    /// Frozen placement engine for this snapshot's topology.
    pub engine: Box<dyn ConsistentHasher>,
    /// Shard handles; bucket id = index.  On a degraded snapshot the
    /// failed buckets' handles are still present (indices never shift)
    /// but must not be contacted — [`is_failed`](Self::is_failed) guards.
    pub shards: Vec<ShardClient>,
    /// `Some` while keys are still being migrated into this topology.
    pub origin: Option<MigrationOrigin>,
    /// `Some` while one or more shards are failed.
    pub degraded: Option<DegradedState>,
    /// Derived replica placements when `replication.factor` > 1; `None`
    /// on factor-1 clusters, which pay nothing for replication support.
    /// Attached centrally by the router's publish path so every epoch's
    /// map matches that epoch's engine.
    pub replicas: Option<ReplicaMap>,
}

/// Failed-shard bookkeeping carried by a degraded [`PlacementSnapshot`].
pub struct DegradedState {
    /// Failed bucket ids, sorted ascending.
    pub failed: Vec<u32>,
    /// One `(placement, bucket)` pair per outstanding failure, in
    /// failure order: the engine is a fork taken immediately *before*
    /// that bucket was removed, so `engine.bucket(d) == bucket`
    /// identifies exactly the keys whose data that failure marooned.  A
    /// per-failure record — rather than one engine frozen at the first
    /// failure — stays correct when the cluster scales *between*
    /// failures: an engine frozen earlier could never name a bucket
    /// that joined after it was forked, and keys marooned on such a
    /// bucket would read as silent misses instead of `UNAVAILABLE`.
    pub maroons: Vec<(Box<dyn ConsistentHasher>, u32)>,
}

/// `a,b,c` rendering for bucket-id lists in STATS and operator-facing
/// errors.
pub(crate) fn bucket_csv(ids: &[u32]) -> String {
    let mut s = String::new();
    for (i, b) in ids.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&b.to_string());
    }
    s
}

impl DegradedState {
    /// Deep copy for the next published snapshot (snapshots are
    /// immutable, so each epoch carries its own fork).
    pub fn fork(&self) -> Self {
        Self {
            failed: self.failed.clone(),
            maroons: self.maroons.iter().map(|(e, b)| (e.fork(), *b)).collect(),
        }
    }

    /// Failed ids as `a,b,c` for STATS and operator-facing errors.
    pub fn failed_csv(&self) -> String {
        bucket_csv(&self.failed)
    }
}

impl PlacementSnapshot {
    /// Map a digest to its bucket and shard handle.
    #[inline]
    pub fn route(&self, digest: u64) -> (u32, &ShardClient) {
        let b = self.engine.bucket(digest);
        (b, &self.shards[b as usize])
    }

    /// `true` while a migration into this topology is in flight.
    pub fn is_migrating(&self) -> bool {
        self.origin.is_some()
    }

    /// `true` while one or more shards are failed.
    pub fn is_degraded(&self) -> bool {
        self.degraded.is_some()
    }

    /// `true` when bucket `b` is failed: its handle must not be
    /// contacted.  O(log #failed), and free (`None` short-circuit) on a
    /// healthy snapshot — the steady-state data path never pays for
    /// failover support.
    #[inline]
    pub fn is_failed(&self, b: u32) -> bool {
        match &self.degraded {
            None => false,
            Some(d) => d.failed.binary_search(&b).is_ok(),
        }
    }

    /// The failed bucket a missing key's data is marooned on, if any:
    /// the earliest outstanding failure whose pre-removal placement
    /// owned the key.  `None` on a healthy snapshot or when the key's
    /// data was never on a dead shard (a genuine miss).  Costs one
    /// engine lookup per outstanding failure, and only on the miss path
    /// of a degraded snapshot.
    #[inline]
    pub fn marooned(&self, digest: u64) -> Option<u32> {
        let d = self.degraded.as_ref()?;
        d.maroons
            .iter()
            .find_map(|(engine, b)| (engine.bucket(digest) == *b).then_some(*b))
    }

    /// The key's rank-1 replica bucket under this snapshot's engine, if
    /// replication is on and one exists.  O(1): one lookup in the
    /// precomputed minus fork (or a bounded probe on rank-1-only
    /// engines).
    #[inline]
    pub fn first_replica(&self, digest: u64, primary: u32) -> Option<u32> {
        self.replicas
            .as_ref()?
            .first_replica(self.engine.as_ref(), digest, primary)
    }

    /// Append the key's full replica set (rank order, primary excluded)
    /// to `out`.  No-op on factor-1 snapshots.
    #[inline]
    pub fn replicas_into(&self, digest: u64, primary: u32, out: &mut Vec<u32>) {
        if let Some(map) = &self.replicas {
            map.replicas_into(self.engine.as_ref(), digest, primary, out);
        }
    }

    /// The batched rank-1 engine for `primary`, when the whole replica
    /// set of this snapshot is exactly rank 1 (`factor == 2`) and the
    /// minus fork exists — the router's batched replica fan-out then
    /// derives a primary-bucket group's replicas in one `bucket_batch`
    /// call instead of one [`replicas_into`](Self::replicas_into) per
    /// key.
    #[inline]
    pub fn rank1_batch_engine(&self, primary: u32) -> Option<&dyn ConsistentHasher> {
        let map = self.replicas.as_ref()?;
        if map.factor() != 2 {
            return None;
        }
        map.rank1_fork(primary)
    }

    /// The *previous* topology's owner of `digest`, when a migration is in
    /// flight and that owner differs from `new_bucket` — i.e. exactly the
    /// keys that may not have reached their new owner yet.
    #[inline]
    pub fn fallback_route(&self, digest: u64, new_bucket: u32) -> Option<(u32, &ShardClient)> {
        let origin = self.origin.as_ref()?;
        let b = origin.engine.bucket(digest);
        if b == new_bucket {
            None
        } else {
            Some((b, &self.shards[b as usize]))
        }
    }
}

/// Cluster state: placement engine + shard handles + event log.
pub struct Cluster {
    /// Monotonic topology epoch.
    pub epoch: u64,
    placement: Box<dyn ConsistentHasher>,
    shards: Vec<ShardClient>,
    /// Topology history.
    pub events: Vec<TopologyEvent>,
}

impl Cluster {
    /// Build from a placement engine and one shard handle per bucket.
    ///
    /// # Panics
    /// Panics if the engine's bucket count differs from the shard count.
    pub fn new(placement: Box<dyn ConsistentHasher>, shards: Vec<ShardClient>) -> Self {
        assert_eq!(
            placement.len() as usize,
            shards.len(),
            "placement engine and shard list disagree"
        );
        Self { epoch: 0, placement, shards, events: Vec::new() }
    }

    /// Number of working buckets.
    pub fn len(&self) -> u32 {
        self.placement.len()
    }

    /// `true` when the cluster has no shards.
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Placement algorithm name.
    pub fn algorithm(&self) -> &'static str {
        self.placement.name()
    }

    /// Map a digest to its bucket.
    #[inline]
    pub fn bucket(&self, digest: u64) -> u32 {
        self.placement.bucket(digest)
    }

    /// Map a digest to its shard handle.
    #[inline]
    pub fn route(&self, digest: u64) -> (u32, &ShardClient) {
        let b = self.placement.bucket(digest);
        (b, &self.shards[b as usize])
    }

    /// Shard handle for a bucket.
    pub fn shard(&self, bucket: u32) -> &ShardClient {
        &self.shards[bucket as usize]
    }

    /// All shard handles (bucket id = index).
    pub fn shards(&self) -> &[ShardClient] {
        &self.shards
    }

    /// Join a new shard; returns its bucket id.
    pub fn join(&mut self, shard: ShardClient) -> u32 {
        let b = self.placement.add_bucket();
        debug_assert_eq!(b as usize, self.shards.len());
        self.shards.push(shard);
        self.epoch += 1;
        self.events.push(TopologyEvent {
            epoch: self.epoch,
            kind: EventKind::Joined(b),
            at: SystemTime::now(),
        });
        b
    }

    /// Consume the cluster into the router's initial placement snapshot
    /// plus the event log recorded so far.
    pub fn into_snapshot(self) -> (PlacementSnapshot, Vec<TopologyEvent>) {
        (
            PlacementSnapshot {
                epoch: self.epoch,
                engine: self.placement,
                shards: self.shards,
                origin: None,
                degraded: None,
                replicas: None,
            },
            self.events,
        )
    }

    /// Remove the last-joined shard; returns `(bucket, handle)`.
    ///
    /// # Panics
    /// Panics if only one shard remains.
    pub fn leave(&mut self) -> (u32, ShardClient) {
        let b = self.placement.remove_bucket();
        let shard = self.shards.pop().expect("shard list in sync");
        debug_assert_eq!(b as usize, self.shards.len());
        self.epoch += 1;
        self.events.push(TopologyEvent {
            epoch: self.epoch,
            kind: EventKind::Left(b),
            at: SystemTime::now(),
        });
        (b, shard)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::binomial::BinomialHash;
    use crate::shard::Shard;

    fn local_cluster(n: u32) -> Cluster {
        let shards = (0..n).map(|i| ShardClient::Local(Shard::new(i))).collect();
        Cluster::new(Box::new(BinomialHash::new(n)), shards)
    }

    #[test]
    fn join_leave_epochs_and_events() {
        let mut c = local_cluster(3);
        assert_eq!(c.len(), 3);
        assert_eq!(c.epoch, 0);
        let b = c.join(ShardClient::Local(Shard::new(3)));
        assert_eq!(b, 3);
        assert_eq!(c.len(), 4);
        assert_eq!(c.epoch, 1);
        let (left, _) = c.leave();
        assert_eq!(left, 3);
        assert_eq!(c.len(), 3);
        assert_eq!(c.epoch, 2);
        assert_eq!(c.events.len(), 2);
        assert_eq!(c.events[0].kind, EventKind::Joined(3));
        assert_eq!(c.events[1].kind, EventKind::Left(3));
    }

    #[test]
    fn route_in_range() {
        let c = local_cluster(5);
        let mut rng = crate::hashing::SplitMix64Rng::new(1);
        for _ in 0..1_000 {
            let (b, _) = c.route(rng.next_u64());
            assert!(b < 5);
        }
    }

    #[test]
    #[should_panic(expected = "disagree")]
    fn mismatched_sizes_panic() {
        let shards = vec![ShardClient::Local(Shard::new(0))];
        Cluster::new(Box::new(BinomialHash::new(2)), shards);
    }

    #[test]
    fn into_snapshot_freezes_state() {
        let mut c = local_cluster(3);
        c.join(ShardClient::Local(Shard::new(3)));
        let (snap, events) = c.into_snapshot();
        assert_eq!(snap.epoch, 1);
        assert_eq!(snap.engine.len(), 4);
        assert_eq!(snap.shards.len(), 4);
        assert!(!snap.is_migrating());
        assert_eq!(events.len(), 1);
        let (b, _) = snap.route(12345);
        assert!(b < 4);
        assert!(snap.fallback_route(12345, b).is_none());
    }

    #[test]
    fn migrating_snapshot_dual_routes() {
        // A snapshot mid scale-up 3 -> 4: keys whose owner changed must
        // report their old owner, and (monotonicity) only keys landing on
        // the new bucket have one.
        let shards: Vec<ShardClient> =
            (0..4).map(|i| ShardClient::Local(Shard::new(i))).collect();
        let snap = PlacementSnapshot {
            epoch: 1,
            engine: Box::new(BinomialHash::new(4)),
            shards,
            origin: Some(MigrationOrigin {
                engine: Box::new(BinomialHash::new(3)),
                sources: vec![0, 1, 2],
                settle_len: 4,
                ae_dest: None,
            }),
            degraded: None,
            replicas: None,
        };
        assert!(snap.is_migrating());
        let mut rng = crate::hashing::SplitMix64Rng::new(3);
        let mut fallbacks = 0;
        for _ in 0..2_000 {
            let d = rng.next_u64();
            let (b, _) = snap.route(d);
            if let Some((ob, _)) = snap.fallback_route(d, b) {
                assert_ne!(ob, b);
                assert_eq!(b, 3, "only keys moving onto the new bucket dual-route");
                assert!(ob < 3);
                fallbacks += 1;
            }
        }
        assert!(fallbacks > 0);
    }

    #[test]
    fn degraded_snapshot_marks_marooned_keys() {
        use crate::algorithms::{memento::MementoHash, ConsistentHasher, FaultTolerant};
        let mut engine = MementoHash::new(4);
        let pre_fail: Box<dyn ConsistentHasher> = engine.fork();
        engine.remove_arbitrary(2);
        let shards: Vec<ShardClient> =
            (0..4).map(|i| ShardClient::Local(Shard::new(i))).collect();
        let snap = PlacementSnapshot {
            epoch: 3,
            engine: Box::new(engine),
            shards,
            origin: None,
            degraded: Some(DegradedState { failed: vec![2], maroons: vec![(pre_fail, 2)] }),
            replicas: None,
        };
        assert!(snap.is_degraded());
        assert!(snap.is_failed(2));
        assert!(!snap.is_failed(1));
        assert_eq!(snap.degraded.as_ref().unwrap().failed_csv(), "2");
        let mut rng = crate::hashing::SplitMix64Rng::new(9);
        let mut marooned = 0;
        for _ in 0..2_000 {
            let d = rng.next_u64();
            let (b, _) = snap.route(d);
            assert_ne!(b, 2, "degraded engine routed to the failed bucket");
            match snap.marooned(d) {
                // Marooned exactly when the healthy placement said 2.
                Some(f) => {
                    assert_eq!(f, 2);
                    marooned += 1;
                }
                None => assert_eq!(
                    snap.degraded.as_ref().unwrap().maroons[0].0.bucket(d),
                    b,
                    "non-marooned keys must not have moved (minimal disruption)"
                ),
            }
        }
        assert!(marooned > 0, "no key was marooned on the failed bucket");
        // A healthy snapshot answers the same queries for free.
        let healthy = PlacementSnapshot {
            epoch: 0,
            engine: Box::new(MementoHash::new(4)),
            shards: (0..4).map(|i| ShardClient::Local(Shard::new(i))).collect(),
            origin: None,
            degraded: None,
            replicas: None,
        };
        assert!(!healthy.is_degraded());
        assert!(!healthy.is_failed(2));
        assert_eq!(healthy.marooned(12345), None);
    }

    #[test]
    fn replica_map_off_below_factor_two_or_two_buckets() {
        let e = BinomialHash::new(4);
        assert!(ReplicaMap::build(&e, 4, 1).is_none());
        let tiny = BinomialHash::new(1);
        assert!(ReplicaMap::build(&tiny, 1, 2).is_none());
    }

    #[test]
    fn ft_replica_matches_degraded_engine_construction() {
        // The load-bearing identity behind FAIL→GET-via-replica: for a
        // fault-tolerant engine the rank-1 replica of a key with
        // primary p is (engine − p).bucket(d) — exactly the placement
        // the failover path publishes after p fails.  So a key's
        // post-FAIL primary IS its pre-FAIL replica.
        use crate::algorithms::memento::MementoHash;
        let engine = MementoHash::new(4);
        let map = ReplicaMap::build(&engine, 4, 2).expect("factor 2 on 4 buckets");
        assert_eq!(map.factor(), 2);
        let mut rng = crate::hashing::SplitMix64Rng::new(21);
        for _ in 0..2_000 {
            let d = rng.next_u64();
            let p = engine.bucket(d);
            let r = map.first_replica(&engine, d, p).expect("replica exists");
            assert_ne!(r, p);
            let mut degraded = engine.fork();
            degraded
                .as_fault_tolerant_mut()
                .expect("memento is fault-tolerant")
                .remove_arbitrary(p);
            assert_eq!(r, degraded.bucket(d), "replica ≠ post-failure owner");
        }
    }

    #[test]
    fn probe_replicas_are_distinct_and_deterministic() {
        // Rank-1-only engines (no fault-tolerant surface) use the
        // re-hash probe: still a pure function of (engine, digest,
        // rank), still distinct from the primary and from each other.
        let engine = BinomialHash::new(5);
        let map = ReplicaMap::build(&engine, 5, 3).expect("factor 3 on 5 buckets");
        let mut rng = crate::hashing::SplitMix64Rng::new(22);
        for _ in 0..1_000 {
            let d = rng.next_u64();
            let p = engine.bucket(d);
            let mut set = Vec::new();
            map.replicas_into(&engine, d, p, &mut set);
            assert_eq!(set.len(), 2);
            assert!(!set.contains(&p));
            assert_ne!(set[0], set[1]);
            assert!(set.iter().all(|b| *b < 5));
            let mut again = Vec::new();
            map.replicas_into(&engine, d, p, &mut again);
            assert_eq!(set, again, "replica derivation must be deterministic");
            assert_eq!(map.first_replica(&engine, d, p), Some(set[0]));
        }
    }

    #[test]
    fn degraded_engine_replicas_avoid_failed_buckets() {
        use crate::algorithms::{memento::MementoHash, FaultTolerant};
        let mut engine = MementoHash::new(5);
        engine.remove_arbitrary(2);
        let map = ReplicaMap::build(&engine, 5, 3).expect("3 of 4 working");
        let mut rng = crate::hashing::SplitMix64Rng::new(23);
        for _ in 0..1_000 {
            let d = rng.next_u64();
            let p = engine.bucket(d);
            let mut set = Vec::new();
            map.replicas_into(&engine, d, p, &mut set);
            assert!(!set.is_empty());
            assert!(!set.contains(&p));
            assert!(!set.contains(&2), "replica landed on the failed bucket");
        }
        // The failed bucket has no minus fork — asking for its replica
        // (it can't be a primary while failed) answers None, not junk.
        assert_eq!(map.first_replica(&engine, 7, 2), None);
    }
}
