//! Cluster membership: the epoch-stamped mapping from buckets to shards.
//!
//! The cluster owns the placement engine (any [`ConsistentHasher`]) and
//! the shard handles, and records every topology change as an event.
//! Shards join and leave in LIFO order (the paper's §1 operating model);
//! arbitrary failures are handled by the Memento-wrapped engine (see
//! `examples/failover_memento.rs`).

use std::time::SystemTime;

use crate::algorithms::ConsistentHasher;
use crate::shard::ShardClient;

/// A topology change.
#[derive(Debug, Clone)]
pub struct TopologyEvent {
    /// Epoch after the change.
    pub epoch: u64,
    /// What happened.
    pub kind: EventKind,
    /// Wall-clock timestamp.
    pub at: SystemTime,
}

/// Event kind.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EventKind {
    /// Bucket joined (always id = n−1 at that epoch).
    Joined(u32),
    /// Bucket left (always the last-added).
    Left(u32),
}

/// Cluster state: placement engine + shard handles + event log.
pub struct Cluster {
    /// Monotonic topology epoch.
    pub epoch: u64,
    placement: Box<dyn ConsistentHasher>,
    shards: Vec<ShardClient>,
    /// Topology history.
    pub events: Vec<TopologyEvent>,
}

impl Cluster {
    /// Build from a placement engine and one shard handle per bucket.
    ///
    /// # Panics
    /// Panics if the engine's bucket count differs from the shard count.
    pub fn new(placement: Box<dyn ConsistentHasher>, shards: Vec<ShardClient>) -> Self {
        assert_eq!(
            placement.len() as usize,
            shards.len(),
            "placement engine and shard list disagree"
        );
        Self { epoch: 0, placement, shards, events: Vec::new() }
    }

    /// Number of working buckets.
    pub fn len(&self) -> u32 {
        self.placement.len()
    }

    /// `true` when the cluster has no shards.
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Placement algorithm name.
    pub fn algorithm(&self) -> &'static str {
        self.placement.name()
    }

    /// Map a digest to its bucket.
    #[inline]
    pub fn bucket(&self, digest: u64) -> u32 {
        self.placement.bucket(digest)
    }

    /// Map a digest to its shard handle.
    #[inline]
    pub fn route(&self, digest: u64) -> (u32, &ShardClient) {
        let b = self.placement.bucket(digest);
        (b, &self.shards[b as usize])
    }

    /// Shard handle for a bucket.
    pub fn shard(&self, bucket: u32) -> &ShardClient {
        &self.shards[bucket as usize]
    }

    /// All shard handles (bucket id = index).
    pub fn shards(&self) -> &[ShardClient] {
        &self.shards
    }

    /// Join a new shard; returns its bucket id.
    pub fn join(&mut self, shard: ShardClient) -> u32 {
        let b = self.placement.add_bucket();
        debug_assert_eq!(b as usize, self.shards.len());
        self.shards.push(shard);
        self.epoch += 1;
        self.events.push(TopologyEvent {
            epoch: self.epoch,
            kind: EventKind::Joined(b),
            at: SystemTime::now(),
        });
        b
    }

    /// Remove the last-joined shard; returns `(bucket, handle)`.
    ///
    /// # Panics
    /// Panics if only one shard remains.
    pub fn leave(&mut self) -> (u32, ShardClient) {
        let b = self.placement.remove_bucket();
        let shard = self.shards.pop().expect("shard list in sync");
        debug_assert_eq!(b as usize, self.shards.len());
        self.epoch += 1;
        self.events.push(TopologyEvent {
            epoch: self.epoch,
            kind: EventKind::Left(b),
            at: SystemTime::now(),
        });
        (b, shard)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::binomial::BinomialHash;
    use crate::shard::Shard;

    fn local_cluster(n: u32) -> Cluster {
        let shards = (0..n).map(|i| ShardClient::Local(Shard::new(i))).collect();
        Cluster::new(Box::new(BinomialHash::new(n)), shards)
    }

    #[test]
    fn join_leave_epochs_and_events() {
        let mut c = local_cluster(3);
        assert_eq!(c.len(), 3);
        assert_eq!(c.epoch, 0);
        let b = c.join(ShardClient::Local(Shard::new(3)));
        assert_eq!(b, 3);
        assert_eq!(c.len(), 4);
        assert_eq!(c.epoch, 1);
        let (left, _) = c.leave();
        assert_eq!(left, 3);
        assert_eq!(c.len(), 3);
        assert_eq!(c.epoch, 2);
        assert_eq!(c.events.len(), 2);
        assert_eq!(c.events[0].kind, EventKind::Joined(3));
        assert_eq!(c.events[1].kind, EventKind::Left(3));
    }

    #[test]
    fn route_in_range() {
        let c = local_cluster(5);
        let mut rng = crate::hashing::SplitMix64Rng::new(1);
        for _ in 0..1_000 {
            let (b, _) = c.route(rng.next_u64());
            assert!(b < 5);
        }
    }

    #[test]
    #[should_panic(expected = "disagree")]
    fn mismatched_sizes_panic() {
        let shards = vec![ShardClient::Local(Shard::new(0))];
        Cluster::new(Box::new(BinomialHash::new(2)), shards);
    }
}
