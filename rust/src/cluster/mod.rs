//! Cluster membership: the epoch-stamped mapping from buckets to shards.
//!
//! Two shapes live here:
//!
//! * [`Cluster`] — the *mutable* construction-time description (placement
//!   engine + shard handles + event log). Shards join and leave in LIFO
//!   order (the paper's §1 operating model); arbitrary failures are
//!   handled by the Memento-wrapped engine (see
//!   `rust/examples/failover_memento.rs`).
//! * [`PlacementSnapshot`] — the *immutable*, epoch-stamped view the
//!   router's data path routes with. The router consumes a `Cluster` into
//!   its first snapshot and publishes a fresh `Arc<PlacementSnapshot>`
//!   (through [`SnapshotCell`](crate::sync::cell::SnapshotCell)) on
//!   every topology change — each epoch's engine is a
//!   [`fork`](crate::algorithms::ConsistentHasher::fork) of the previous
//!   epoch's, never a by-name rebuild — so GET/PUT/DEL never contend with
//!   a migration and stateful engines keep their full placement state.
//!   While keys are still in flight the snapshot carries a
//!   [`MigrationOrigin`] — a fork of the previous epoch's engine —
//!   enabling dual-read (new owner, then old owner) routing.
//!
//! A snapshot can additionally be **degraded** ([`DegradedState`]): one
//! or more shards have *failed* (arbitrary removal, not LIFO retirement).
//! The engine — a fault-tolerant one, reached through
//! [`as_fault_tolerant_mut`](crate::algorithms::ConsistentHasher::as_fault_tolerant_mut)
//! on a fork — already routes every key to a survivor; the degraded state
//! records *which* bucket ids are dead (their shard handles stay in
//! `shards` so indices never shift, but must never be contacted) and the
//! pre-failure placement, so a miss on a key whose data is marooned on a
//! dead shard answers a distinguishable `UNAVAILABLE` error instead of
//! `NIL` — or worse, a hang on a dead connection.

use std::time::SystemTime;

use crate::algorithms::ConsistentHasher;
use crate::shard::ShardClient;

/// A topology change.
#[derive(Debug, Clone)]
pub struct TopologyEvent {
    /// Epoch after the change.
    pub epoch: u64,
    /// What happened.
    pub kind: EventKind,
    /// Wall-clock timestamp.
    pub at: SystemTime,
}

/// Event kind.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EventKind {
    /// Bucket joined (always id = n−1 at that epoch).
    Joined(u32),
    /// Bucket left (always the last-added).
    Left(u32),
    /// Bucket failed (arbitrary removal; data marooned until restore).
    Failed(u32),
    /// Bucket restored after a failure (rejoins empty; keys written to
    /// survivors while it was down migrate back to it).
    Restored(u32),
}

/// The previous topology's placement, kept inside a migrating
/// [`PlacementSnapshot`] so the data path can fall back to a key's old
/// owner until the migration sweep has copied it.
pub struct MigrationOrigin {
    /// Placement engine of the epoch being migrated away from (an
    /// unmodified fork of that epoch's engine).
    pub engine: Box<dyn ConsistentHasher>,
    /// Bucket ids the migration scans for movable keys: every *reachable*
    /// old shard on scale-up and on a failed-shard restore, but only the
    /// retiring shard on scale-down when the engine guarantees minimal
    /// disruption (engines without it — maglev, modulo — scan everything
    /// there too).  A list, not a range, because a degraded topology has
    /// holes: a dead shard must never be scanned.
    pub sources: Vec<u32>,
    /// Shard-list length once this migration settles: one less than the
    /// migrating snapshot's list on scale-down (the retiring handle is
    /// dropped), unchanged otherwise.  Recorded explicitly so an
    /// interrupted migration can be resumed and settled without
    /// inferring the intent from engine/list length arithmetic — which
    /// breaks down on degraded topologies, where the engine's working
    /// count is always below the slot count.
    pub settle_len: usize,
}

/// An immutable, epoch-stamped placement view: frozen engine + shard
/// handles + optional in-flight migration origin.
///
/// Published by the router through an atomic pointer swap (a hand-rolled
/// std-only arc-swap; see `router` for the reader-gate protocol); never
/// mutated after publication, so the data path reads it lock-free — one
/// atomic load plus a refcount bump, no `RwLock` anywhere.
/// During a migration the shard list covers the *union* of the old and
/// new topologies (scale-down keeps the retiring shard reachable for
/// dual reads until the final snapshot drops it).
pub struct PlacementSnapshot {
    /// Epoch this snapshot was published at (monotonically non-decreasing
    /// across publications).
    pub epoch: u64,
    /// Frozen placement engine for this snapshot's topology.
    pub engine: Box<dyn ConsistentHasher>,
    /// Shard handles; bucket id = index.  On a degraded snapshot the
    /// failed buckets' handles are still present (indices never shift)
    /// but must not be contacted — [`is_failed`](Self::is_failed) guards.
    pub shards: Vec<ShardClient>,
    /// `Some` while keys are still being migrated into this topology.
    pub origin: Option<MigrationOrigin>,
    /// `Some` while one or more shards are failed.
    pub degraded: Option<DegradedState>,
}

/// Failed-shard bookkeeping carried by a degraded [`PlacementSnapshot`].
pub struct DegradedState {
    /// Failed bucket ids, sorted ascending.
    pub failed: Vec<u32>,
    /// One `(placement, bucket)` pair per outstanding failure, in
    /// failure order: the engine is a fork taken immediately *before*
    /// that bucket was removed, so `engine.bucket(d) == bucket`
    /// identifies exactly the keys whose data that failure marooned.  A
    /// per-failure record — rather than one engine frozen at the first
    /// failure — stays correct when the cluster scales *between*
    /// failures: an engine frozen earlier could never name a bucket
    /// that joined after it was forked, and keys marooned on such a
    /// bucket would read as silent misses instead of `UNAVAILABLE`.
    pub maroons: Vec<(Box<dyn ConsistentHasher>, u32)>,
}

/// `a,b,c` rendering for bucket-id lists in STATS and operator-facing
/// errors.
pub(crate) fn bucket_csv(ids: &[u32]) -> String {
    let mut s = String::new();
    for (i, b) in ids.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&b.to_string());
    }
    s
}

impl DegradedState {
    /// Deep copy for the next published snapshot (snapshots are
    /// immutable, so each epoch carries its own fork).
    pub fn fork(&self) -> Self {
        Self {
            failed: self.failed.clone(),
            maroons: self.maroons.iter().map(|(e, b)| (e.fork(), *b)).collect(),
        }
    }

    /// Failed ids as `a,b,c` for STATS and operator-facing errors.
    pub fn failed_csv(&self) -> String {
        bucket_csv(&self.failed)
    }
}

impl PlacementSnapshot {
    /// Map a digest to its bucket and shard handle.
    #[inline]
    pub fn route(&self, digest: u64) -> (u32, &ShardClient) {
        let b = self.engine.bucket(digest);
        (b, &self.shards[b as usize])
    }

    /// `true` while a migration into this topology is in flight.
    pub fn is_migrating(&self) -> bool {
        self.origin.is_some()
    }

    /// `true` while one or more shards are failed.
    pub fn is_degraded(&self) -> bool {
        self.degraded.is_some()
    }

    /// `true` when bucket `b` is failed: its handle must not be
    /// contacted.  O(log #failed), and free (`None` short-circuit) on a
    /// healthy snapshot — the steady-state data path never pays for
    /// failover support.
    #[inline]
    pub fn is_failed(&self, b: u32) -> bool {
        match &self.degraded {
            None => false,
            Some(d) => d.failed.binary_search(&b).is_ok(),
        }
    }

    /// The failed bucket a missing key's data is marooned on, if any:
    /// the earliest outstanding failure whose pre-removal placement
    /// owned the key.  `None` on a healthy snapshot or when the key's
    /// data was never on a dead shard (a genuine miss).  Costs one
    /// engine lookup per outstanding failure, and only on the miss path
    /// of a degraded snapshot.
    #[inline]
    pub fn marooned(&self, digest: u64) -> Option<u32> {
        let d = self.degraded.as_ref()?;
        d.maroons
            .iter()
            .find_map(|(engine, b)| (engine.bucket(digest) == *b).then_some(*b))
    }

    /// The *previous* topology's owner of `digest`, when a migration is in
    /// flight and that owner differs from `new_bucket` — i.e. exactly the
    /// keys that may not have reached their new owner yet.
    #[inline]
    pub fn fallback_route(&self, digest: u64, new_bucket: u32) -> Option<(u32, &ShardClient)> {
        let origin = self.origin.as_ref()?;
        let b = origin.engine.bucket(digest);
        if b == new_bucket {
            None
        } else {
            Some((b, &self.shards[b as usize]))
        }
    }
}

/// Cluster state: placement engine + shard handles + event log.
pub struct Cluster {
    /// Monotonic topology epoch.
    pub epoch: u64,
    placement: Box<dyn ConsistentHasher>,
    shards: Vec<ShardClient>,
    /// Topology history.
    pub events: Vec<TopologyEvent>,
}

impl Cluster {
    /// Build from a placement engine and one shard handle per bucket.
    ///
    /// # Panics
    /// Panics if the engine's bucket count differs from the shard count.
    pub fn new(placement: Box<dyn ConsistentHasher>, shards: Vec<ShardClient>) -> Self {
        assert_eq!(
            placement.len() as usize,
            shards.len(),
            "placement engine and shard list disagree"
        );
        Self { epoch: 0, placement, shards, events: Vec::new() }
    }

    /// Number of working buckets.
    pub fn len(&self) -> u32 {
        self.placement.len()
    }

    /// `true` when the cluster has no shards.
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Placement algorithm name.
    pub fn algorithm(&self) -> &'static str {
        self.placement.name()
    }

    /// Map a digest to its bucket.
    #[inline]
    pub fn bucket(&self, digest: u64) -> u32 {
        self.placement.bucket(digest)
    }

    /// Map a digest to its shard handle.
    #[inline]
    pub fn route(&self, digest: u64) -> (u32, &ShardClient) {
        let b = self.placement.bucket(digest);
        (b, &self.shards[b as usize])
    }

    /// Shard handle for a bucket.
    pub fn shard(&self, bucket: u32) -> &ShardClient {
        &self.shards[bucket as usize]
    }

    /// All shard handles (bucket id = index).
    pub fn shards(&self) -> &[ShardClient] {
        &self.shards
    }

    /// Join a new shard; returns its bucket id.
    pub fn join(&mut self, shard: ShardClient) -> u32 {
        let b = self.placement.add_bucket();
        debug_assert_eq!(b as usize, self.shards.len());
        self.shards.push(shard);
        self.epoch += 1;
        self.events.push(TopologyEvent {
            epoch: self.epoch,
            kind: EventKind::Joined(b),
            at: SystemTime::now(),
        });
        b
    }

    /// Consume the cluster into the router's initial placement snapshot
    /// plus the event log recorded so far.
    pub fn into_snapshot(self) -> (PlacementSnapshot, Vec<TopologyEvent>) {
        (
            PlacementSnapshot {
                epoch: self.epoch,
                engine: self.placement,
                shards: self.shards,
                origin: None,
                degraded: None,
            },
            self.events,
        )
    }

    /// Remove the last-joined shard; returns `(bucket, handle)`.
    ///
    /// # Panics
    /// Panics if only one shard remains.
    pub fn leave(&mut self) -> (u32, ShardClient) {
        let b = self.placement.remove_bucket();
        let shard = self.shards.pop().expect("shard list in sync");
        debug_assert_eq!(b as usize, self.shards.len());
        self.epoch += 1;
        self.events.push(TopologyEvent {
            epoch: self.epoch,
            kind: EventKind::Left(b),
            at: SystemTime::now(),
        });
        (b, shard)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::binomial::BinomialHash;
    use crate::shard::Shard;

    fn local_cluster(n: u32) -> Cluster {
        let shards = (0..n).map(|i| ShardClient::Local(Shard::new(i))).collect();
        Cluster::new(Box::new(BinomialHash::new(n)), shards)
    }

    #[test]
    fn join_leave_epochs_and_events() {
        let mut c = local_cluster(3);
        assert_eq!(c.len(), 3);
        assert_eq!(c.epoch, 0);
        let b = c.join(ShardClient::Local(Shard::new(3)));
        assert_eq!(b, 3);
        assert_eq!(c.len(), 4);
        assert_eq!(c.epoch, 1);
        let (left, _) = c.leave();
        assert_eq!(left, 3);
        assert_eq!(c.len(), 3);
        assert_eq!(c.epoch, 2);
        assert_eq!(c.events.len(), 2);
        assert_eq!(c.events[0].kind, EventKind::Joined(3));
        assert_eq!(c.events[1].kind, EventKind::Left(3));
    }

    #[test]
    fn route_in_range() {
        let c = local_cluster(5);
        let mut rng = crate::hashing::SplitMix64Rng::new(1);
        for _ in 0..1_000 {
            let (b, _) = c.route(rng.next_u64());
            assert!(b < 5);
        }
    }

    #[test]
    #[should_panic(expected = "disagree")]
    fn mismatched_sizes_panic() {
        let shards = vec![ShardClient::Local(Shard::new(0))];
        Cluster::new(Box::new(BinomialHash::new(2)), shards);
    }

    #[test]
    fn into_snapshot_freezes_state() {
        let mut c = local_cluster(3);
        c.join(ShardClient::Local(Shard::new(3)));
        let (snap, events) = c.into_snapshot();
        assert_eq!(snap.epoch, 1);
        assert_eq!(snap.engine.len(), 4);
        assert_eq!(snap.shards.len(), 4);
        assert!(!snap.is_migrating());
        assert_eq!(events.len(), 1);
        let (b, _) = snap.route(12345);
        assert!(b < 4);
        assert!(snap.fallback_route(12345, b).is_none());
    }

    #[test]
    fn migrating_snapshot_dual_routes() {
        // A snapshot mid scale-up 3 -> 4: keys whose owner changed must
        // report their old owner, and (monotonicity) only keys landing on
        // the new bucket have one.
        let shards: Vec<ShardClient> =
            (0..4).map(|i| ShardClient::Local(Shard::new(i))).collect();
        let snap = PlacementSnapshot {
            epoch: 1,
            engine: Box::new(BinomialHash::new(4)),
            shards,
            origin: Some(MigrationOrigin {
                engine: Box::new(BinomialHash::new(3)),
                sources: vec![0, 1, 2],
                settle_len: 4,
            }),
            degraded: None,
        };
        assert!(snap.is_migrating());
        let mut rng = crate::hashing::SplitMix64Rng::new(3);
        let mut fallbacks = 0;
        for _ in 0..2_000 {
            let d = rng.next_u64();
            let (b, _) = snap.route(d);
            if let Some((ob, _)) = snap.fallback_route(d, b) {
                assert_ne!(ob, b);
                assert_eq!(b, 3, "only keys moving onto the new bucket dual-route");
                assert!(ob < 3);
                fallbacks += 1;
            }
        }
        assert!(fallbacks > 0);
    }

    #[test]
    fn degraded_snapshot_marks_marooned_keys() {
        use crate::algorithms::{memento::MementoHash, ConsistentHasher, FaultTolerant};
        let mut engine = MementoHash::new(4);
        let pre_fail: Box<dyn ConsistentHasher> = engine.fork();
        engine.remove_arbitrary(2);
        let shards: Vec<ShardClient> =
            (0..4).map(|i| ShardClient::Local(Shard::new(i))).collect();
        let snap = PlacementSnapshot {
            epoch: 3,
            engine: Box::new(engine),
            shards,
            origin: None,
            degraded: Some(DegradedState { failed: vec![2], maroons: vec![(pre_fail, 2)] }),
        };
        assert!(snap.is_degraded());
        assert!(snap.is_failed(2));
        assert!(!snap.is_failed(1));
        assert_eq!(snap.degraded.as_ref().unwrap().failed_csv(), "2");
        let mut rng = crate::hashing::SplitMix64Rng::new(9);
        let mut marooned = 0;
        for _ in 0..2_000 {
            let d = rng.next_u64();
            let (b, _) = snap.route(d);
            assert_ne!(b, 2, "degraded engine routed to the failed bucket");
            match snap.marooned(d) {
                // Marooned exactly when the healthy placement said 2.
                Some(f) => {
                    assert_eq!(f, 2);
                    marooned += 1;
                }
                None => assert_eq!(
                    snap.degraded.as_ref().unwrap().maroons[0].0.bucket(d),
                    b,
                    "non-marooned keys must not have moved (minimal disruption)"
                ),
            }
        }
        assert!(marooned > 0, "no key was marooned on the failed bucket");
        // A healthy snapshot answers the same queries for free.
        let healthy = PlacementSnapshot {
            epoch: 0,
            engine: Box::new(MementoHash::new(4)),
            shards: (0..4).map(|i| ShardClient::Local(Shard::new(i))).collect(),
            origin: None,
            degraded: None,
        };
        assert!(!healthy.is_degraded());
        assert!(!healthy.is_failed(2));
        assert_eq!(healthy.marooned(12345), None);
    }
}
