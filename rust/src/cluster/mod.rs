//! Cluster membership: the epoch-stamped mapping from buckets to shards.
//!
//! Two shapes live here:
//!
//! * [`Cluster`] — the *mutable* construction-time description (placement
//!   engine + shard handles + event log). Shards join and leave in LIFO
//!   order (the paper's §1 operating model); arbitrary failures are
//!   handled by the Memento-wrapped engine (see
//!   `rust/examples/failover_memento.rs`).
//! * [`PlacementSnapshot`] — the *immutable*, epoch-stamped view the
//!   router's data path routes with. The router consumes a `Cluster` into
//!   its first snapshot and publishes a fresh `Arc<PlacementSnapshot>` on
//!   every topology change — each epoch's engine is a
//!   [`fork`](crate::algorithms::ConsistentHasher::fork) of the previous
//!   epoch's, never a by-name rebuild — so GET/PUT/DEL never contend with
//!   a migration and stateful engines keep their full placement state.
//!   While keys are still in flight the snapshot carries a
//!   [`MigrationOrigin`] — a fork of the previous epoch's engine —
//!   enabling dual-read (new owner, then old owner) routing.

use std::time::SystemTime;

use crate::algorithms::ConsistentHasher;
use crate::shard::ShardClient;

/// A topology change.
#[derive(Debug, Clone)]
pub struct TopologyEvent {
    /// Epoch after the change.
    pub epoch: u64,
    /// What happened.
    pub kind: EventKind,
    /// Wall-clock timestamp.
    pub at: SystemTime,
}

/// Event kind.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EventKind {
    /// Bucket joined (always id = n−1 at that epoch).
    Joined(u32),
    /// Bucket left (always the last-added).
    Left(u32),
}

/// The previous topology's placement, kept inside a migrating
/// [`PlacementSnapshot`] so the data path can fall back to a key's old
/// owner until the migration sweep has copied it.
pub struct MigrationOrigin {
    /// Placement engine of the epoch being migrated away from (an
    /// unmodified fork of that epoch's engine).
    pub engine: Box<dyn ConsistentHasher>,
    /// Bucket range the migration scans for movable keys: every old shard
    /// on scale-up, but only the retiring shard on scale-down when the
    /// engine guarantees minimal disruption (engines without it — maglev,
    /// modulo — scan everything there too).
    pub sources: std::ops::Range<u32>,
}

/// An immutable, epoch-stamped placement view: frozen engine + shard
/// handles + optional in-flight migration origin.
///
/// Published by the router through an atomic pointer swap (a hand-rolled
/// std-only arc-swap; see `router` for the reader-gate protocol); never
/// mutated after publication, so the data path reads it lock-free — one
/// atomic load plus a refcount bump, no `RwLock` anywhere.
/// During a migration the shard list covers the *union* of the old and
/// new topologies (scale-down keeps the retiring shard reachable for
/// dual reads until the final snapshot drops it).
pub struct PlacementSnapshot {
    /// Epoch this snapshot was published at (monotonically non-decreasing
    /// across publications).
    pub epoch: u64,
    /// Frozen placement engine for this snapshot's topology.
    pub engine: Box<dyn ConsistentHasher>,
    /// Shard handles; bucket id = index.
    pub shards: Vec<ShardClient>,
    /// `Some` while keys are still being migrated into this topology.
    pub origin: Option<MigrationOrigin>,
}

impl PlacementSnapshot {
    /// Map a digest to its bucket and shard handle.
    #[inline]
    pub fn route(&self, digest: u64) -> (u32, &ShardClient) {
        let b = self.engine.bucket(digest);
        (b, &self.shards[b as usize])
    }

    /// `true` while a migration into this topology is in flight.
    pub fn is_migrating(&self) -> bool {
        self.origin.is_some()
    }

    /// The *previous* topology's owner of `digest`, when a migration is in
    /// flight and that owner differs from `new_bucket` — i.e. exactly the
    /// keys that may not have reached their new owner yet.
    #[inline]
    pub fn fallback_route(&self, digest: u64, new_bucket: u32) -> Option<(u32, &ShardClient)> {
        let origin = self.origin.as_ref()?;
        let b = origin.engine.bucket(digest);
        if b == new_bucket {
            None
        } else {
            Some((b, &self.shards[b as usize]))
        }
    }
}

/// Cluster state: placement engine + shard handles + event log.
pub struct Cluster {
    /// Monotonic topology epoch.
    pub epoch: u64,
    placement: Box<dyn ConsistentHasher>,
    shards: Vec<ShardClient>,
    /// Topology history.
    pub events: Vec<TopologyEvent>,
}

impl Cluster {
    /// Build from a placement engine and one shard handle per bucket.
    ///
    /// # Panics
    /// Panics if the engine's bucket count differs from the shard count.
    pub fn new(placement: Box<dyn ConsistentHasher>, shards: Vec<ShardClient>) -> Self {
        assert_eq!(
            placement.len() as usize,
            shards.len(),
            "placement engine and shard list disagree"
        );
        Self { epoch: 0, placement, shards, events: Vec::new() }
    }

    /// Number of working buckets.
    pub fn len(&self) -> u32 {
        self.placement.len()
    }

    /// `true` when the cluster has no shards.
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Placement algorithm name.
    pub fn algorithm(&self) -> &'static str {
        self.placement.name()
    }

    /// Map a digest to its bucket.
    #[inline]
    pub fn bucket(&self, digest: u64) -> u32 {
        self.placement.bucket(digest)
    }

    /// Map a digest to its shard handle.
    #[inline]
    pub fn route(&self, digest: u64) -> (u32, &ShardClient) {
        let b = self.placement.bucket(digest);
        (b, &self.shards[b as usize])
    }

    /// Shard handle for a bucket.
    pub fn shard(&self, bucket: u32) -> &ShardClient {
        &self.shards[bucket as usize]
    }

    /// All shard handles (bucket id = index).
    pub fn shards(&self) -> &[ShardClient] {
        &self.shards
    }

    /// Join a new shard; returns its bucket id.
    pub fn join(&mut self, shard: ShardClient) -> u32 {
        let b = self.placement.add_bucket();
        debug_assert_eq!(b as usize, self.shards.len());
        self.shards.push(shard);
        self.epoch += 1;
        self.events.push(TopologyEvent {
            epoch: self.epoch,
            kind: EventKind::Joined(b),
            at: SystemTime::now(),
        });
        b
    }

    /// Consume the cluster into the router's initial placement snapshot
    /// plus the event log recorded so far.
    pub fn into_snapshot(self) -> (PlacementSnapshot, Vec<TopologyEvent>) {
        (
            PlacementSnapshot {
                epoch: self.epoch,
                engine: self.placement,
                shards: self.shards,
                origin: None,
            },
            self.events,
        )
    }

    /// Remove the last-joined shard; returns `(bucket, handle)`.
    ///
    /// # Panics
    /// Panics if only one shard remains.
    pub fn leave(&mut self) -> (u32, ShardClient) {
        let b = self.placement.remove_bucket();
        let shard = self.shards.pop().expect("shard list in sync");
        debug_assert_eq!(b as usize, self.shards.len());
        self.epoch += 1;
        self.events.push(TopologyEvent {
            epoch: self.epoch,
            kind: EventKind::Left(b),
            at: SystemTime::now(),
        });
        (b, shard)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::binomial::BinomialHash;
    use crate::shard::Shard;

    fn local_cluster(n: u32) -> Cluster {
        let shards = (0..n).map(|i| ShardClient::Local(Shard::new(i))).collect();
        Cluster::new(Box::new(BinomialHash::new(n)), shards)
    }

    #[test]
    fn join_leave_epochs_and_events() {
        let mut c = local_cluster(3);
        assert_eq!(c.len(), 3);
        assert_eq!(c.epoch, 0);
        let b = c.join(ShardClient::Local(Shard::new(3)));
        assert_eq!(b, 3);
        assert_eq!(c.len(), 4);
        assert_eq!(c.epoch, 1);
        let (left, _) = c.leave();
        assert_eq!(left, 3);
        assert_eq!(c.len(), 3);
        assert_eq!(c.epoch, 2);
        assert_eq!(c.events.len(), 2);
        assert_eq!(c.events[0].kind, EventKind::Joined(3));
        assert_eq!(c.events[1].kind, EventKind::Left(3));
    }

    #[test]
    fn route_in_range() {
        let c = local_cluster(5);
        let mut rng = crate::hashing::SplitMix64Rng::new(1);
        for _ in 0..1_000 {
            let (b, _) = c.route(rng.next_u64());
            assert!(b < 5);
        }
    }

    #[test]
    #[should_panic(expected = "disagree")]
    fn mismatched_sizes_panic() {
        let shards = vec![ShardClient::Local(Shard::new(0))];
        Cluster::new(Box::new(BinomialHash::new(2)), shards);
    }

    #[test]
    fn into_snapshot_freezes_state() {
        let mut c = local_cluster(3);
        c.join(ShardClient::Local(Shard::new(3)));
        let (snap, events) = c.into_snapshot();
        assert_eq!(snap.epoch, 1);
        assert_eq!(snap.engine.len(), 4);
        assert_eq!(snap.shards.len(), 4);
        assert!(!snap.is_migrating());
        assert_eq!(events.len(), 1);
        let (b, _) = snap.route(12345);
        assert!(b < 4);
        assert!(snap.fallback_route(12345, b).is_none());
    }

    #[test]
    fn migrating_snapshot_dual_routes() {
        // A snapshot mid scale-up 3 -> 4: keys whose owner changed must
        // report their old owner, and (monotonicity) only keys landing on
        // the new bucket have one.
        let shards: Vec<ShardClient> =
            (0..4).map(|i| ShardClient::Local(Shard::new(i))).collect();
        let snap = PlacementSnapshot {
            epoch: 1,
            engine: Box::new(BinomialHash::new(4)),
            shards,
            origin: Some(MigrationOrigin {
                engine: Box::new(BinomialHash::new(3)),
                sources: 0..3,
            }),
        };
        assert!(snap.is_migrating());
        let mut rng = crate::hashing::SplitMix64Rng::new(3);
        let mut fallbacks = 0;
        for _ in 0..2_000 {
            let d = rng.next_u64();
            let (b, _) = snap.route(d);
            if let Some((ob, _)) = snap.fallback_route(d, b) {
                assert_ne!(ob, b);
                assert_eq!(b, 3, "only keys moving onto the new bucket dual-route");
                assert!(ob < 3);
                fallbacks += 1;
            }
        }
        assert!(fallbacks > 0);
    }
}
