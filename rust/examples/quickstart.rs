//! Quickstart: the BinomialHash public API in two minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks through: constant-time lookups, the paper's three consistency
//! properties under scaling, and the closed-form balance guarantees.

use binhash::algorithms::binomial::BinomialHash;
use binhash::algorithms::ConsistentHasher;
use binhash::stats::{theory, BalanceStats};
use binhash::workload::UniformDigests;

fn main() {
    // --- 1. Create a hasher for an 11-node cluster (the paper's example).
    let mut ch = BinomialHash::new(11);
    println!("BinomialHash n=11: enclosing tree E={}, minor tree M={}",
             ch.enclosing_capacity(), ch.minor_capacity());

    // --- 2. Constant-time lookups: any key digest -> bucket in [0, 11).
    let bucket = ch.bucket_for_key(b"users/4217/profile.json");
    println!("users/4217/profile.json -> bucket {bucket}");
    assert!(bucket < 11);

    // --- 3. Monotonicity: scaling 11 -> 12 moves keys ONLY to bucket 11.
    let keys = UniformDigests::new(42).take_vec(100_000);
    let before: Vec<u32> = keys.iter().map(|&d| ch.bucket(d)).collect();
    ch.add_bucket();
    let mut moved = 0;
    for (&d, &b) in keys.iter().zip(&before) {
        let now = ch.bucket(d);
        assert!(now == b || now == 11, "monotonicity violated");
        if now != b {
            moved += 1;
        }
    }
    println!(
        "scale-up 11->12: {moved}/100000 keys moved ({:.2}%, ideal {:.2}%), all to bucket 11",
        moved as f64 / 1000.0,
        100.0 / 12.0
    );

    // --- 4. Minimal disruption: scaling 12 -> 11 moves only bucket 11's keys.
    let at12: Vec<u32> = keys.iter().map(|&d| ch.bucket(d)).collect();
    ch.remove_bucket();
    for (&d, &b) in keys.iter().zip(&at12) {
        let now = ch.bucket(d);
        if b != 11 {
            assert_eq!(now, b, "minimal disruption violated");
        }
    }
    println!("scale-down 12->11: only bucket 11's keys relocated");

    // --- 5. Balance: relative stddev under the paper's Eq. 5/6 bounds.
    let mut counts = vec![0u64; 11];
    for &d in &keys {
        counts[ch.bucket(d) as usize] += 1;
    }
    let s = BalanceStats::from_counts(&counts);
    println!(
        "balance over 100k keys: mean={:.0} stddev={:.1} ({:.2}% relative; \
         Eq.5 predicts {:.1})",
        s.mean,
        s.stddev,
        100.0 * s.rel_stddev(),
        theory::stddev(11, ch.omega(), 100_000)
    );

    // --- 6. The whole state is 8 bytes: n + omega. Snapshot = copy.
    let snapshot = ch; // Copy
    println!("state size: {} bytes (Copy)", std::mem::size_of_val(&snapshot));

    // --- 7. Every engine forks: the router scales by forking the live
    // epoch's engine and resizing the fork, while the parent keeps
    // routing — this works identically for stateful engines (anchor, dx,
    // memento), whose state a by-name rebuild could not reproduce.
    let mut next_epoch = ch.fork();
    next_epoch.add_bucket();
    assert_eq!(ch.len(), 11);
    assert_eq!(next_epoch.len(), 12);
    println!(
        "fork: next epoch routes over n={} while the live epoch stays at n={}",
        next_epoch.len(),
        ch.len()
    );
    println!("\nquickstart OK");
}
