//! Arbitrary node failures with the Memento-wrapped engine (paper §7).
//!
//! ```bash
//! cargo run --release --example failover_memento
//! ```
//!
//! The core BinomialHash supports LIFO scaling only; the paper points to
//! MementoHash for random failures.  This example fails random nodes out
//! of a 20-node cluster, verifies minimal disruption and uniform
//! redistribution at every step, then restores them and verifies the
//! mapping returns exactly to its pre-failure state.

use binhash::algorithms::memento::MementoHash;
use binhash::algorithms::{ConsistentHasher, FaultTolerant};
use binhash::stats::BalanceStats;
use binhash::workload::UniformDigests;

const NODES: u32 = 20;
const KEYS: usize = 200_000;

fn main() {
    let mut m = MementoHash::new(NODES);
    let digests = UniformDigests::new(0xFA_11).take_vec(KEYS);
    let healthy: Vec<u32> = digests.iter().map(|&d| m.bucket(d)).collect();
    println!("cluster: {NODES} nodes, {KEYS} keys placed");

    // --- Fail 5 random-ish nodes one at a time.
    let failures = [13u32, 2, 19, 7, 11];
    let mut prev = healthy.clone();
    for (step, &f) in failures.iter().enumerate() {
        m.remove_arbitrary(f);
        let now: Vec<u32> = digests.iter().map(|&d| m.bucket(d)).collect();
        let mut relocated = 0usize;
        for (i, (&was, &is)) in prev.iter().zip(&now).enumerate() {
            assert!(m.is_working(is), "key {i} routed to failed node {is}");
            if was != is {
                assert_eq!(was, f, "minimal disruption violated: key moved off healthy node {was}");
                relocated += 1;
            }
        }
        let working = m.len();
        println!(
            "step {}: failed node {f} -> {relocated} keys relocated \
             ({:.2}%, ideal 1/{} = {:.2}%), {working} nodes working",
            step + 1,
            100.0 * relocated as f64 / KEYS as f64,
            NODES - step as u32,
            100.0 / (NODES - step as u32) as f64,
        );
        prev = now;
    }

    // --- Balance across survivors.
    let mut counts = vec![0u64; NODES as usize];
    for &d in &digests {
        counts[m.bucket(d) as usize] += 1;
    }
    let surviving: Vec<u64> =
        (0..NODES).filter(|&b| m.is_working(b)).map(|b| counts[b as usize]).collect();
    let s = BalanceStats::from_counts(&surviving);
    println!(
        "balance across {} survivors: mean={:.0}, rel stddev={:.2}%",
        surviving.len(),
        s.mean,
        100.0 * s.rel_stddev()
    );
    for &f in &failures {
        assert_eq!(counts[f as usize], 0, "failed node still receives keys");
    }

    // --- Restore everything; mapping must be exactly the healthy one.
    for &f in failures.iter().rev() {
        m.restore(f);
    }
    let restored: Vec<u32> = digests.iter().map(|&d| m.bucket(d)).collect();
    assert_eq!(restored, healthy, "restore did not return the original mapping");
    println!("all nodes restored: mapping identical to pre-failure state");

    // --- And LIFO scaling still works once failures are cleared.
    m.add_bucket();
    assert_eq!(m.len(), NODES + 1);
    println!("LIFO scale-up to {} nodes after recovery", m.len());
    println!("\nfailover_memento OK");
}
