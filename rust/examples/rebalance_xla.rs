//! Bulk placement via the AOT XLA artifacts (the three-layer story).
//!
//! ```bash
//! make artifacts && cargo run --release --example rebalance_xla
//! ```
//!
//! Loads the JAX/Pallas-lowered HLO artifacts through PJRT, computes the
//! migration plan for 1M keys across a 64 → 65 scale-up entirely on the
//! compiled graph, verifies bit-parity with the pure-Rust implementation,
//! and compares throughput of the two bulk paths.

use std::time::Instant;

use anyhow::{Context, Result};

use binhash::algorithms::binomial;
use binhash::runtime::PlacementRuntime;
use binhash::workload::UniformDigests;

const KEYS: usize = 1 << 20; // 1M
const N_OLD: u32 = 64;
const N_NEW: u32 = 65;

fn main() -> Result<()> {
    let runtime = PlacementRuntime::load("artifacts")
        .context("artifacts missing — run `make artifacts` first")?;
    println!("PJRT runtime up (omega={})", runtime.omega);
    let omega = runtime.omega;
    let digests = UniformDigests::new(0xA0_7).take_vec(KEYS);

    // --- Bulk lookup on the XLA path (best of 3: steady-state, first call
    // includes PJRT warm-up).
    let mut xla_dt = std::time::Duration::MAX;
    let mut xla_buckets = Vec::new();
    for _ in 0..3 {
        let t0 = Instant::now();
        xla_buckets = runtime.lookup_batch(&digests, N_OLD)?;
        xla_dt = xla_dt.min(t0.elapsed());
    }

    // --- Same computation in pure Rust (best of 3).
    let mut rust_dt = std::time::Duration::MAX;
    let mut rust_buckets = Vec::new();
    for _ in 0..3 {
        let t0 = Instant::now();
        rust_buckets = digests.iter().map(|&d| binomial::lookup(d, N_OLD, omega)).collect();
        rust_dt = rust_dt.min(t0.elapsed());
    }

    // --- Bit parity: the Pallas kernel IS the Rust algorithm.
    assert_eq!(xla_buckets, rust_buckets, "XLA artifact diverges from Rust");
    println!(
        "lookup_batch({KEYS} keys, n={N_OLD}): XLA {:.0}ms ({:.1}M keys/s) | \
         Rust {:.0}ms ({:.1}M keys/s) — results bit-identical",
        xla_dt.as_secs_f64() * 1e3,
        KEYS as f64 / xla_dt.as_secs_f64() / 1e6,
        rust_dt.as_secs_f64() * 1e3,
        KEYS as f64 / rust_dt.as_secs_f64() / 1e6,
    );

    // --- Migration plan on the XLA path (old + new placement fused).
    let t0 = Instant::now();
    let plan = runtime.migration_plan(&digests, N_OLD, N_NEW)?;
    let plan_dt = t0.elapsed();
    let moved_frac = plan.moved_count as f64 / KEYS as f64;
    println!(
        "migration_plan {N_OLD}->{N_NEW}: {} keys move ({:.3}%, ideal 1/{N_NEW} = {:.3}%) \
         in {:.0}ms",
        plan.moved_count,
        100.0 * moved_frac,
        100.0 / N_NEW as f64,
        plan_dt.as_secs_f64() * 1e3,
    );
    // Monotonicity on the bulk path: every move lands on the new bucket.
    for i in 0..KEYS {
        if plan.moved[i] != 0 {
            assert_eq!(plan.new[i], N_OLD, "bulk move not onto the new bucket");
        } else {
            assert_eq!(plan.new[i], plan.old[i]);
        }
    }
    println!("monotonicity verified on the bulk path (all moves -> bucket {N_OLD})");

    // --- Balance histogram offload.
    let counts = runtime.histogram(&digests, N_OLD)?;
    let total: u64 = counts.iter().sum();
    assert_eq!(total, KEYS as u64);
    let stats = binhash::stats::BalanceStats::from_counts(&counts);
    println!(
        "histogram offload: {} buckets, rel stddev {:.2}%",
        counts.len(),
        100.0 * stats.rel_stddev()
    );

    println!("\nrebalance_xla OK");
    Ok(())
}
