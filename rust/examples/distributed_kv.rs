//! End-to-end driver: a real distributed KV cluster on the full stack.
//!
//! ```bash
//! cargo run --release --example distributed_kv
//! ```
//!
//! Spins a router + 8 TCP shard servers (real sockets, real wire
//! protocol), loads 200k objects under a zipfian workload, serves mixed
//! GET/PUT traffic from 4 concurrent clients, scales the cluster 8 → 12 →
//! 8 with live rebalancing, and reports the paper's headline metrics:
//! placement latency (constant-time), balance (relative stddev), and
//! movement fraction vs the consistent-hashing ideal.
//!
//! This is the repo's end-to-end smoke run; the per-phase perf numbers
//! that CI tracks live in `BENCH_router.json` (see `benches/router_hotpath.rs`).

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use binhash::cluster::Cluster;
use binhash::proto::Request;
use binhash::router::Router;
use binhash::shard::{RemotePool, Shard, ShardClient};
use binhash::stats::BalanceStats;
use binhash::workload::ZipfKeys;

const INITIAL_SHARDS: u32 = 8;
const OBJECTS: usize = 200_000;
const TRAFFIC_OPS: usize = 100_000;
const CLIENTS: usize = 4;

fn spawn_tcp_shard(id: u32) -> Result<ShardClient> {
    let shard = Shard::new(id);
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    std::thread::spawn(move || {
        let _ = binhash::shard::serve(shard, listener);
    });
    Ok(ShardClient::Remote(RemotePool::new(addr, 4)))
}

fn balance_report(router: &Arc<Router>) -> Result<BalanceStats> {
    // Per-shard key counts via the router's stats path.
    let (_, n, _) = router.topology();
    let mut counts = Vec::new();
    for b in 0..n {
        // Count per shard through the cluster handle is not exposed over
        // the wire; use COUNT per shard via SCAN-less accounting: issue a
        // Stats and parse? Simplest: the router exposes Count for totals;
        // here we scan shards directly through the topology snapshot.
        counts.push(router.shard_count(b)?);
    }
    Ok(BalanceStats::from_counts(&counts))
}

fn main() -> Result<()> {
    // --- Build the cluster: 8 real TCP shards behind the router.
    let shards: Vec<ShardClient> =
        (0..INITIAL_SHARDS).map(spawn_tcp_shard).collect::<Result<_>>()?;
    let placement = binhash::algorithms::by_name("binomial", INITIAL_SHARDS).unwrap();
    let cluster = Cluster::new(placement, shards);
    let router = Router::with_options(
        cluster,
        Box::new(|id| spawn_tcp_shard(id).expect("spawn shard")),
        None,
    );
    println!("cluster up: {INITIAL_SHARDS} TCP shards, binomial placement");

    // --- Load phase: 200k zipfian objects.
    let t0 = Instant::now();
    let mut zipf = ZipfKeys::new(1, OBJECTS, 0.99);
    let mut loaded = 0usize;
    for _ in 0..OBJECTS {
        let (key, _) = zipf.next_key();
        router.handle(Request::Put { key, value: vec![0xAB; 64].into() });
        loaded += 1;
    }
    let load_s = t0.elapsed().as_secs_f64();
    println!(
        "load: {loaded} PUTs in {load_s:.1}s ({:.0} op/s)",
        loaded as f64 / load_s
    );

    // --- Mixed traffic phase: 4 concurrent clients, 90% GET / 10% PUT.
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let router = router.clone();
        handles.push(std::thread::spawn(move || {
            let mut zipf = ZipfKeys::new(100 + c as u64, OBJECTS, 0.99);
            let mut hits = 0usize;
            for i in 0..TRAFFIC_OPS / CLIENTS {
                let (key, _) = zipf.next_key();
                if i % 10 == 0 {
                    router.handle(Request::Put { key, value: vec![1; 64].into() });
                } else if !matches!(
                    router.handle(Request::Get { key }),
                    binhash::proto::Response::Nil
                ) {
                    hits += 1;
                }
            }
            hits
        }));
    }
    let hits: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let traffic_s = t0.elapsed().as_secs_f64();
    println!(
        "traffic: {TRAFFIC_OPS} mixed ops from {CLIENTS} clients in {traffic_s:.1}s \
         ({:.0} op/s), {hits} GET hits",
        TRAFFIC_OPS as f64 / traffic_s
    );
    println!(
        "latency: e2e p50={}ns p99={}ns | placement p50={}ns p99={}ns (constant-time)",
        router.metrics.latency.quantile_ns(0.5),
        router.metrics.latency.quantile_ns(0.99),
        router.metrics.placement_latency.quantile_ns(0.5),
        router.metrics.placement_latency.quantile_ns(0.99),
    );

    // --- Balance before scaling.
    let s = balance_report(&router)?;
    println!(
        "balance @ n=8: mean={:.0} keys/shard, rel stddev={:.2}% (paper: <4%)",
        s.mean,
        100.0 * s.rel_stddev()
    );

    // --- Scale up 8 -> 12, one shard at a time, measuring movement.
    let stored = match router.handle(Request::Count) {
        binhash::proto::Response::Num(x) => x as f64,
        other => panic!("{other:?}"),
    };
    println!("unique objects stored: {stored} (zipf draws collide on hot keys)");
    for target in 9..=12u32 {
        let before = router.handle(Request::Count);
        let t0 = Instant::now();
        router.handle(Request::ScaleUp);
        let dt = t0.elapsed().as_secs_f64();
        let after = router.handle(Request::Count);
        assert_eq!(before, after, "keys lost during scale-up");
        let moved = router.metrics.migrated_keys.swap(0, std::sync::atomic::Ordering::Relaxed);
        println!(
            "scale-up -> {target}: moved {moved} keys ({:.2}%, ideal 1/n = {:.2}%) in {dt:.2}s",
            100.0 * moved as f64 / stored,
            100.0 / target as f64
        );
    }
    let s = balance_report(&router)?;
    println!("balance @ n=12: rel stddev={:.2}%", 100.0 * s.rel_stddev());

    // --- Scale back down 12 -> 8.
    for target in (8..=11u32).rev() {
        router.handle(Request::ScaleDown);
        let moved = router.metrics.migrated_keys.swap(0, std::sync::atomic::Ordering::Relaxed);
        println!(
            "scale-down -> {target}: moved {moved} keys ({:.2}%, ideal {:.2}%)",
            100.0 * moved as f64 / stored,
            100.0 / (target + 1) as f64
        );
    }

    // --- Final integrity check: every loaded object still readable.
    let mut zipf = ZipfKeys::new(1, OBJECTS, 0.99);
    let mut missing = 0;
    for _ in 0..5_000 {
        let (key, _) = zipf.next_key();
        if matches!(router.handle(Request::Get { key }), binhash::proto::Response::Nil) {
            missing += 1;
        }
    }
    assert_eq!(missing, 0, "objects lost across the scale cycle");
    println!("integrity: 5000/5000 sampled objects present after 8->12->8 cycle");
    println!("\n{}", router.metrics.summary());
    println!("distributed_kv OK");
    Ok(())
}
